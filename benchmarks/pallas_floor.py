"""Fused-timestep floor: pallas_step vs fused, and launch amortization vs S.

Fig-1-style sweep at the finest grain (iterations=1), where wall time per
step measures the runtime's per-step control path, not arithmetic — the
regime where the paper's METG collapses. Two measurements:

  1. `fused` vs `pallas_step` (PR 2): one gather + masked-mean chain + body
     op per step vs the whole step as one fused kernel. Acceptance:
     pallas_step's wall/step STRICTLY lower than fused's at every width.
  2. Temporal blocking (this PR): pallas_step with steps_per_launch =
     S in {1, 2, 4, 8, 16} (+ the VMEM auto-tuner's pick). S timesteps
     share one kernel launch and one deep-halo exchange, so launches and
     exchanges per run drop by S x. The sweep runs MULTI-device (default
     4): per-step cost at S=1 is dominated by the ring collective's
     device rendezvous, which is precisely what blocking amortizes (on 1
     device the exchange is an identity permute that XLA folds away, so
     there is nothing left to amortize and the sweep would only measure
     noise). Acceptance: wall/step monotonically non-increasing in S,
     with S=8 at least 1.5x under S=1.

All variants of a width run back-to-back in ONE worker process
(SweepSpec.compare_runtimes / option_variants), so ratios are not polluted
by scheduling differences across workers. Outputs:

  artifacts/bench/pallas_floor.csv   one row per (width, backend, variant)
  artifacts/bench/pallas_floor.json  summary incl. per-width ratios, the
                                     strictly-lower verdict, and the
                                     steps_per_launch sweep + verdicts

``--smoke`` shrinks the sweep to a seconds-long CI guard (tiny width/steps,
no timing assertions — it exists so the launch-amortization artifact and
the blocked code path can never silently bit-rot).
"""
from __future__ import annotations

import argparse
import json

from benchmarks.common import (
    SweepSpec,
    backend_options_args,
    bench_path,
    parse_backend_options,
    run_worker,
    write_csv,
)

from repro.configs.taskbench import PRESETS

WIDTHS = (64, 256, 1024, 4096)
#: temporal-blocking depths swept (plus the auto-tuner row); widths for the
#: sweep are kept moderate so the deep halo (2*S*r extra rows) stays a
#: small fraction of the block and the measurement isolates launch count
SWEEP_S = (1, 2, 4, 8, 16)
SWEEP_WIDTHS = (256, 1024)
SWEEP_DEVICES = 4


def _per_step_walls(rows, steps, runtime):
    """variant label -> best wall/step for one runtime's rows."""
    walls = {}
    for r in rows:
        if "skip" in r or r["runtime"] != runtime:
            continue
        lbl = r.get("variant", "")
        per_step = r["wall"] / steps
        walls[lbl] = min(walls.get(lbl, per_step), per_step)
    return walls


def run(devices: int = 1, steps: int = 0, reps: int = 0,
        widths=WIDTHS, sweep_widths=SWEEP_WIDTHS, sweep_s=SWEEP_S,
        sweep_devices: int = SWEEP_DEVICES, payload: int = 64,
        options=None, verbose: bool = True, smoke: bool = False):
    cfg = PRESETS["floor"]
    steps = steps or cfg.steps
    reps = reps or cfg.reps
    rows_out = []
    ratios = {}

    # ---- 1. fused vs pallas_step (per-step launches, S=1) -----------------
    for width in widths:
        spec = SweepSpec(
            runtime=cfg.runtimes[0], compare_runtimes=cfg.runtimes,
            pattern="stencil_1d", devices=devices, width=width,
            steps=steps, grains=cfg.grains, reps=reps, payload=payload,
            options=dict(options or {}),
        )
        rows = run_worker(spec)
        walls = {}
        for r in rows:
            if "skip" in r:
                if verbose:
                    print(f"floor {r['runtime']:12s} W={width}: skip — "
                          f"{r['skip']}", flush=True)
                continue
            per_step = r["wall"] / steps
            walls[r["runtime"]] = per_step
            rows_out.append([r["runtime"], "", width, r["grain"], steps,
                             r["wall"], per_step, r["gran_us"],
                             r["dispatches"]])
        if "fused" in walls and "pallas_step" in walls:
            ratios[str(width)] = walls["pallas_step"] / walls["fused"]
            if verbose:
                print(f"floor W={width:5d}: fused "
                      f"{walls['fused']*1e6:9.2f} us/step, pallas_step "
                      f"{walls['pallas_step']*1e6:9.2f} us/step  "
                      f"(ratio {ratios[str(width)]:.3f})", flush=True)

    # ---- 2. steps_per_launch sweep (launch amortization) ------------------
    variants = {f"S{s}": {"steps_per_launch": s} for s in sweep_s}
    variants["Sauto"] = {"steps_per_launch": "auto"}
    sweep = {}
    for width in sweep_widths:
        spec = SweepSpec(
            runtime="pallas_step", pattern="stencil_1d",
            devices=sweep_devices, width=width, steps=steps,
            # deep-S walls are short (tens of us/step x steps), so the
            # best-of needs more reps than part 1 to beat scheduler jitter
            # on the multiplexed host devices
            grains=cfg.grains, reps=max(reps, 10) if not smoke else reps,
            payload=payload, options=dict(options or {}),
            option_variants=variants,
        )
        rows = run_worker(spec)
        walls = _per_step_walls(rows, steps, "pallas_step")
        sweep[str(width)] = walls
        for r in rows:
            if "skip" in r:
                continue
            rows_out.append([r["runtime"], r.get("variant", ""), width,
                             r["grain"], steps, r["wall"], r["wall"] / steps,
                             r["gran_us"], r["dispatches"]])
        if verbose and walls:
            ladder = "  ".join(
                f"{lbl}={walls[lbl]*1e6:.2f}us"
                for lbl in sorted(walls, key=lambda x: (len(x), x)))
            print(f"floor W={width:5d} steps_per_launch: {ladder}",
                  flush=True)

    # verdicts over the numeric ladder (auto row reported but not judged)
    monotone = bool(sweep)
    s8_speedups = {}
    for width, walls in sweep.items():
        ladder = [walls.get(f"S{s}") for s in sweep_s]
        ladder = [w for w in ladder if w is not None]
        monotone = monotone and all(
            b <= a for a, b in zip(ladder, ladder[1:]))
        if walls.get("S1") and walls.get("S8"):
            s8_speedups[width] = walls["S1"] / walls["S8"]
    amortization_ok = bool(s8_speedups) and all(
        v >= 1.5 for v in s8_speedups.values())

    strictly_lower = bool(ratios) and all(v < 1.0 for v in ratios.values())
    path_csv = write_csv(
        "pallas_floor.csv",
        ["backend", "variant", "width", "grain", "steps", "wall_s",
         "wall_per_step_s", "granularity_us", "dispatches"],
        rows_out,
    )
    path_json = bench_path("pallas_floor.json")
    with open(path_json, "w") as f:
        json.dump({
            "devices": devices, "sweep_devices": sweep_devices,
            "steps": steps, "payload": payload,
            "grain_iterations": list(cfg.grains),
            "smoke": smoke,
            "pallas_over_fused_per_step": ratios,
            "pallas_step_strictly_lower": strictly_lower,
            "steps_per_launch_values": list(sweep_s),
            "steps_per_launch_sweep": sweep,
            "s1_over_s8_speedup": s8_speedups,
            "sweep_monotone_nonincreasing": monotone,
            "amortization_ok_s8_1p5x": amortization_ok,
        }, f, indent=2)
    if verbose:
        print(f"pallas_step strictly lower wall/step than fused: "
              f"{strictly_lower}")
        if sweep:
            print(f"steps_per_launch sweep monotone: {monotone}; "
                  f"S1/S8 speedups: "
                  + ", ".join(f"W={w}: {v:.2f}x"
                              for w, v in sorted(s8_speedups.items(),
                                                 key=lambda kv: int(kv[0]))))
        print(f"wrote {path_csv} and {path_json}")
    return {"ratios": ratios, "strictly_lower": strictly_lower,
            "sweep": sweep, "monotone": monotone,
            "s8_speedups": s8_speedups, "amortization_ok": amortization_ok}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--steps", type=int, default=0,
                    help="override the floor preset's step count")
    ap.add_argument("--reps", type=int, default=0)
    ap.add_argument("--widths", default=",".join(str(w) for w in WIDTHS))
    ap.add_argument("--sweep-widths",
                    default=",".join(str(w) for w in SWEEP_WIDTHS),
                    help="widths for the steps_per_launch sweep")
    ap.add_argument("--sweep-s", default=",".join(str(s) for s in SWEEP_S),
                    help="steps_per_launch depths to sweep")
    ap.add_argument("--sweep-devices", type=int, default=SWEEP_DEVICES,
                    help="device count for the steps_per_launch sweep "
                         "(multi-device: the per-step collective is the "
                         "cost blocking amortizes)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-long CI guard: tiny sweep, no assertions")
    backend_options_args(ap)
    a = ap.parse_args(argv)
    opts = parse_backend_options(a)
    if a.smoke:
        res = run(devices=a.devices, steps=17, reps=1, widths=(64,),
                  sweep_widths=(64,), sweep_s=(1, 2, 4, 8),
                  sweep_devices=2, options=opts, smoke=True)
        # the smoke run guards the CODE PATHS (blocked kernel, deep
        # exchange, artifact schema), not the timing verdicts — but every
        # swept width must have actually produced variant rows (a width
        # whose variants were all skipped means the blocked path never ran)
        ok = bool(res["sweep"]) and all(res["sweep"].values())
        return 0 if ok else 1
    run(devices=a.devices, steps=a.steps, reps=a.reps,
        widths=tuple(int(w) for w in a.widths.split(",")),
        sweep_widths=tuple(int(w) for w in a.sweep_widths.split(",")),
        sweep_s=tuple(int(s) for s in a.sweep_s.split(",")),
        sweep_devices=a.sweep_devices, options=opts)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
