"""Fused-timestep floor: pallas_step vs fused wall/step at iterations=1.

Fig-1-style sweep at the finest grain (iterations=1), where wall time per
step measures the runtime's per-step control path, not arithmetic — the
regime where the paper's METG collapses. `fused` pays one gather + one
masked-mean chain + one body op per step; `pallas_step` executes the whole
step as one fused kernel whose combine is a static chain of shifted-slice
FMAs (see DESIGN.md §4). The recorded acceptance check: pallas_step's
wall/step is STRICTLY lower than fused's at every width.

Both backends run back-to-back in one worker process per width
(SweepSpec.compare_runtimes), so the ratio is not polluted by scheduling
differences across workers. Outputs:

  artifacts/bench/pallas_floor.csv   one row per (width, backend)
  artifacts/bench/pallas_floor.json  summary incl. per-width ratios and the
                                     strictly-lower verdict
"""
from __future__ import annotations

import argparse
import json

from benchmarks.common import (
    SweepSpec,
    backend_options_args,
    bench_path,
    parse_backend_options,
    run_worker,
    write_csv,
)

from repro.configs.taskbench import PRESETS

WIDTHS = (64, 256, 1024, 4096)


def run(devices: int = 1, steps: int = 0, reps: int = 0,
        widths=WIDTHS, payload: int = 64, options=None, verbose: bool = True):
    cfg = PRESETS["floor"]
    steps = steps or cfg.steps
    reps = reps or cfg.reps
    rows_out = []
    ratios = {}
    for width in widths:
        spec = SweepSpec(
            runtime=cfg.runtimes[0], compare_runtimes=cfg.runtimes,
            pattern="stencil_1d", devices=devices, width=width,
            steps=steps, grains=cfg.grains, reps=reps, payload=payload,
            options=dict(options or {}),
        )
        rows = run_worker(spec)
        walls = {}
        for r in rows:
            if "skip" in r:
                if verbose:
                    print(f"floor {r['runtime']:12s} W={width}: skip — "
                          f"{r['skip']}", flush=True)
                continue
            per_step = r["wall"] / steps
            walls[r["runtime"]] = per_step
            rows_out.append([r["runtime"], width, r["grain"], steps,
                             r["wall"], per_step, r["gran_us"],
                             r["dispatches"]])
        if "fused" in walls and "pallas_step" in walls:
            ratios[str(width)] = walls["pallas_step"] / walls["fused"]
            if verbose:
                print(f"floor W={width:5d}: fused "
                      f"{walls['fused']*1e6:9.2f} us/step, pallas_step "
                      f"{walls['pallas_step']*1e6:9.2f} us/step  "
                      f"(ratio {ratios[str(width)]:.3f})", flush=True)

    strictly_lower = bool(ratios) and all(v < 1.0 for v in ratios.values())
    path_csv = write_csv(
        "pallas_floor.csv",
        ["backend", "width", "grain", "steps", "wall_s", "wall_per_step_s",
         "granularity_us", "dispatches"],
        rows_out,
    )
    path_json = bench_path("pallas_floor.json")
    with open(path_json, "w") as f:
        json.dump({
            "devices": devices, "steps": steps, "payload": payload,
            "grain_iterations": list(cfg.grains),
            "pallas_over_fused_per_step": ratios,
            "pallas_step_strictly_lower": strictly_lower,
        }, f, indent=2)
    if verbose:
        print(f"pallas_step strictly lower wall/step than fused: "
              f"{strictly_lower}")
        print(f"wrote {path_csv} and {path_json}")
    return {"ratios": ratios, "strictly_lower": strictly_lower}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--steps", type=int, default=0,
                    help="override the floor preset's step count")
    ap.add_argument("--reps", type=int, default=0)
    ap.add_argument("--widths", default=",".join(str(w) for w in WIDTHS))
    backend_options_args(ap)
    a = ap.parse_args(argv)
    opts = parse_backend_options(a)
    run(devices=a.devices, steps=a.steps, reps=a.reps,
        widths=tuple(int(w) for w in a.widths.split(",")), options=opts)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
