"""Fused-timestep floor: pallas_step vs fused, launch amortization vs S,
and the double-buffered deep-halo pipeline vs its serial-exchange ablation.

Fig-1-style sweep at the finest grain (iterations=1), where wall time per
step measures the runtime's per-step control path, not arithmetic — the
regime where the paper's METG collapses. Three measurements:

  1. `fused` vs `pallas_step` (PR 2): one gather + masked-mean chain + body
     op per step vs the whole step as one fused kernel. Acceptance:
     pallas_step's wall/step STRICTLY lower than fused's at every width.
  2. Temporal blocking (PR 3): pallas_step with steps_per_launch =
     S in {1, 2, 4, 8, 16} (+ the VMEM auto-tuner's pick). S timesteps
     share one kernel launch and one deep-halo exchange, so launches and
     exchanges per run drop by S x. The sweep runs MULTI-device (default
     4): per-step cost at S=1 is dominated by the ring collective's
     device rendezvous, which is precisely what blocking amortizes (on 1
     device the exchange is an identity permute that XLA folds away, so
     there is nothing left to amortize and the sweep would only measure
     noise). Acceptance: wall/step monotonically non-increasing in S,
     with S=8 at least 1.5x under S=1.
  3. Pipeline (PR 4): at the TUNED S (kernels/schedule.py with
     pipeline=True), pipeline=True vs the pipeline=False ablation —
     the serial-exchange schedule every deep exchange previously sat in.
     The pair is measured in interleaved ROUNDS inside one worker (pipe,
     nopipe, pipe, nopipe, ...) and best-of taken per label, because on
     this container the collective rendezvous cost drifts with machine
     load far more than the effect size. Acceptance: pipelined wall/step
     <= 0.85x of the ablation's.
  4. Butterfly floor (this PR): fused vs pallas_step on the NON-LOCAL
     fft/tree patterns — the stride plan's per-slot megakernel launches
     against fused's per-step gather/combine/body chain — so the floor
     artifact finally covers the paper's butterfly scenarios, not just
     nearest-neighbor ones. Acceptance: pallas_step wall/step at or
     below fused's at every butterfly width (iterations=1).

All variants of a width run back-to-back in ONE worker process
(SweepSpec.compare_runtimes / option_variants), so ratios are not polluted
by scheduling differences across workers. Outputs:

  artifacts/bench/pallas_floor.csv   one row per (width, backend, variant)
  artifacts/bench/pallas_floor.json  summary incl. per-width ratios, the
                                     strictly-lower verdict, the
                                     steps_per_launch sweep + verdicts,
                                     and the pipeline speedup at tuned S

``--smoke`` shrinks the sweep to a seconds-long CI guard (tiny width/steps
— it exists so the launch-amortization artifact and the blocked +
pipelined + butterfly code paths can never silently bit-rot) and writes to
``pallas_floor_smoke.{csv,json}`` so the committed full-run artifacts
survive a smoke run. Smoke JSONs record every timing VERDICT as null: the
shapes are too small to judge (e.g. steps=17 gives the pipeline ~2 blocked
launches — no steady state), so a boolean either way would be a false
claim in the committed baseline; the raw walls/ratios are still recorded
and ``benchmarks.floor_guard`` compares them against the committed
``pallas_floor_smoke_baseline.json``.
"""
from __future__ import annotations

import argparse
import json

from benchmarks.common import (
    SweepSpec,
    backend_options_args,
    bench_path,
    calibrate_worker,
    parse_backend_options,
    run_worker,
    write_csv,
)

from repro.configs.taskbench import PRESETS
from repro.kernels import schedule as _schedule

WIDTHS = (64, 256, 1024, 4096)
#: temporal-blocking depths swept (plus the auto-tuner row); widths for the
#: sweep are kept moderate so the deep halo (2*S*r extra rows) stays a
#: small fraction of the block and the measurement isolates launch count
SWEEP_S = (1, 2, 4, 8, 16)
SWEEP_WIDTHS = (256, 1024)
SWEEP_DEVICES = 4
#: widths for the pipeline-vs-ablation pair (need a block wide enough that
#: the interior covers the exchange at the tuned S — see kernels/schedule)
PIPE_WIDTHS = (512, 1024)
#: interleaved measurement rounds for the pipeline pair (noise resistance)
PIPE_ROUNDS = 4
#: butterfly-floor widths (power of two, graph-validated for fft/tree)
BUTTERFLY_WIDTHS = (64, 256, 1024)
BUTTERFLY_PATTERNS = ("fft", "tree")


def _per_step_walls(rows, steps, runtime):
    """variant label -> best wall/step for one runtime's rows."""
    walls = {}
    for r in rows:
        if "skip" in r or r["runtime"] != runtime:
            continue
        lbl = r.get("variant", "")
        per_step = r["wall"] / steps
        walls[lbl] = min(walls.get(lbl, per_step), per_step)
    return walls


def run(devices: int = 1, steps: int = 0, reps: int = 0,
        widths=WIDTHS, sweep_widths=SWEEP_WIDTHS, sweep_s=SWEEP_S,
        sweep_devices: int = SWEEP_DEVICES, pipe_widths=PIPE_WIDTHS,
        butterfly_widths=BUTTERFLY_WIDTHS,
        butterfly_patterns=BUTTERFLY_PATTERNS,
        payload: int = 64, options=None, verbose: bool = True,
        smoke: bool = False, calibrate: bool = False):
    cfg = PRESETS["floor"]
    steps = steps or cfg.steps
    reps = reps or cfg.reps
    rows_out = []
    ratios = {}

    # the cost-model snapshot the artifact records: every saved verdict
    # names the constants it was judged under. --calibrate measures fresh
    # probes first (merged into the cache, so the sweeps below resolve
    # "auto" through them); otherwise snapshot whatever the default
    # resolution currently is (env / cached / analytic).
    if calibrate:
        cost_model = calibrate_worker(sweep_devices, payload, smoke=smoke)
        if verbose:
            print(f"calibrated cost model: exchange="
                  f"{cost_model['exchange_row_steps']:.0f} row-steps, "
                  f"launch={cost_model['launch_us']:.1f}us", flush=True)
    else:
        from repro.kernels import probes as _probes

        cost_model = _probes.default_cost_model(
            devices=sweep_devices, payload=payload).to_dict()

    # ---- 1. fused vs pallas_step (per-step launches, S=1) -----------------
    for width in widths:
        spec = SweepSpec(
            runtime=cfg.runtimes[0], compare_runtimes=cfg.runtimes,
            pattern="stencil_1d", devices=devices, width=width,
            steps=steps, grains=cfg.grains, reps=reps, payload=payload,
            options=dict(options or {}),
            # smoke rows also record a span trace (a separate traced
            # execution after the timed reps — the walls are untouched),
            # so every CI run ships a decomposed + Chrome-loadable view
            # of the floor row alongside the scalar artifact
            trace=smoke, trace_dir=bench_path("traces") if smoke else "",
        )
        rows = run_worker(spec)
        walls = {}
        for r in rows:
            if "skip" in r:
                if verbose:
                    print(f"floor {r['runtime']:12s} W={width}: skip — "
                          f"{r['skip']}", flush=True)
                continue
            per_step = r["wall"] / steps
            walls[r["runtime"]] = per_step
            rows_out.append([r["runtime"], "", width, r["grain"], steps,
                             r["wall"], per_step, r["gran_us"],
                             r["dispatches"]])
        if "fused" in walls and "pallas_step" in walls:
            ratios[str(width)] = walls["pallas_step"] / walls["fused"]
            if verbose:
                print(f"floor W={width:5d}: fused "
                      f"{walls['fused']*1e6:9.2f} us/step, pallas_step "
                      f"{walls['pallas_step']*1e6:9.2f} us/step  "
                      f"(ratio {ratios[str(width)]:.3f})", flush=True)

    # ---- 1b. butterfly floor (fused vs pallas_step on fft/tree) -----------
    butterfly = {}        # pattern -> {width: pallas/fused wall ratio}
    butterfly_floor = {}  # "pattern@width" -> pallas wall/step (guarded)
    for pattern in butterfly_patterns:
        for width in butterfly_widths:
            spec = SweepSpec(
                runtime=cfg.runtimes[0], compare_runtimes=cfg.runtimes,
                pattern=pattern, devices=devices, width=width,
                steps=steps, grains=cfg.grains, reps=reps, payload=payload,
                options=dict(options or {}),
            )
            rows = run_worker(spec)
            walls = {}
            for r in rows:
                if "skip" in r:
                    if verbose:
                        print(f"floor {r['runtime']:12s} {pattern} "
                              f"W={width}: skip — {r['skip']}", flush=True)
                    continue
                per_step = r["wall"] / steps
                walls[r["runtime"]] = per_step
                rows_out.append([r["runtime"], pattern, width, r["grain"],
                                 steps, r["wall"], per_step, r["gran_us"],
                                 r["dispatches"]])
            if "fused" in walls and "pallas_step" in walls:
                ratio = walls["pallas_step"] / walls["fused"]
                butterfly.setdefault(pattern, {})[str(width)] = ratio
                butterfly_floor[f"{pattern}@{width}"] = walls["pallas_step"]
                if verbose:
                    print(f"floor {pattern} W={width:5d}: fused "
                          f"{walls['fused']*1e6:9.2f} us/step, pallas_step "
                          f"{walls['pallas_step']*1e6:9.2f} us/step  "
                          f"(ratio {ratio:.3f})", flush=True)
    butterfly_ok = bool(butterfly) and all(
        v <= 1.0 for by in butterfly.values() for v in by.values())

    # ---- 2. steps_per_launch sweep (launch amortization) ------------------
    variants = {f"S{s}": {"steps_per_launch": s} for s in sweep_s}
    variants["Sauto"] = {"steps_per_launch": "auto"}
    sweep = {}
    for width in sweep_widths:
        spec = SweepSpec(
            runtime="pallas_step", pattern="stencil_1d",
            devices=sweep_devices, width=width, steps=steps,
            # deep-S walls are short (tens of us/step x steps), so the
            # best-of needs more reps than part 1 to beat scheduler jitter
            # on the multiplexed host devices
            grains=cfg.grains, reps=max(reps, 10) if not smoke else reps,
            payload=payload, options=dict(options or {}),
            option_variants=variants,
        )
        rows = run_worker(spec)
        walls = _per_step_walls(rows, steps, "pallas_step")
        sweep[str(width)] = walls
        for r in rows:
            if "skip" in r:
                continue
            rows_out.append([r["runtime"], r.get("variant", ""), width,
                             r["grain"], steps, r["wall"], r["wall"] / steps,
                             r["gran_us"], r["dispatches"]])
        if verbose and walls:
            ladder = "  ".join(
                f"{lbl}={walls[lbl]*1e6:.2f}us"
                for lbl in sorted(walls, key=lambda x: (len(x), x)))
            print(f"floor W={width:5d} steps_per_launch: {ladder}",
                  flush=True)

    # ---- 3. pipeline vs serial-exchange ablation at the tuned S -----------
    pipeline = {}
    for width in pipe_widths:
        tuned = _schedule.choose_steps_per_launch(
            block=width // sweep_devices, radius=1, payload=payload,
            total_steps=steps, combine="window", pipeline=True)
        pair = {"pipe": {"steps_per_launch": tuned},
                "nopipe": {"steps_per_launch": tuned, "pipeline": False}}
        # interleaved rounds: pipe#0, nopipe#0, pipe#1, ... so machine-load
        # drift hits both labels alike; best-of folds the rounds per label
        rounds = 1 if smoke else PIPE_ROUNDS
        pvariants = {f"{lbl}#{i}": opts for i in range(rounds)
                     for lbl, opts in pair.items()}
        spec = SweepSpec(
            runtime="pallas_step", pattern="stencil_1d",
            devices=sweep_devices, width=width, steps=steps,
            grains=cfg.grains, reps=max(reps, 10) if not smoke else reps,
            payload=payload, options=dict(options or {}),
            option_variants=pvariants,
        )
        rows = run_worker(spec)
        raw = _per_step_walls(rows, steps, "pallas_step")
        walls = {}
        for lbl, w in raw.items():
            base = lbl.split("#")[0]
            walls[base] = min(walls.get(base, w), w)
        for r in rows:
            if "skip" in r:
                continue
            rows_out.append([r["runtime"], f"S{tuned}:{r['variant']}", width,
                             r["grain"], steps, r["wall"], r["wall"] / steps,
                             r["gran_us"], r["dispatches"]])
        if "pipe" in walls and "nopipe" in walls:
            pipeline[str(width)] = {
                "steps_per_launch": tuned,
                "pipe_wall_per_step": walls["pipe"],
                "nopipe_wall_per_step": walls["nopipe"],
                "pipe_over_nopipe": walls["pipe"] / walls["nopipe"],
            }
            if verbose:
                print(f"floor W={width:5d} pipeline@S{tuned}: "
                      f"pipe {walls['pipe']*1e6:.2f}us "
                      f"nopipe {walls['nopipe']*1e6:.2f}us "
                      f"(ratio {walls['pipe']/walls['nopipe']:.3f})",
                      flush=True)

    # verdicts over the numeric ladder (auto row reported but not judged).
    # A SMOKE run records every timing verdict as None: its shapes are
    # too small to judge (steps=17 gives the pipeline ~2 blocked launches
    # — no steady state to win in), and a boolean either way would be a
    # false claim in a committed baseline. Smoke guards code paths and
    # the artifact schema; the full run owns the verdicts.
    monotone = bool(sweep)
    s8_speedups = {}
    for width, walls in sweep.items():
        ladder = [walls.get(f"S{s}") for s in sweep_s]
        ladder = [w for w in ladder if w is not None]
        monotone = monotone and all(
            b <= a for a, b in zip(ladder, ladder[1:]))
        if walls.get("S1") and walls.get("S8"):
            s8_speedups[width] = walls["S1"] / walls["S8"]
    amortization_ok = bool(s8_speedups) and all(
        v >= 1.5 for v in s8_speedups.values())
    pipeline_ok = bool(pipeline) and all(
        v["pipe_over_nopipe"] <= 0.85 for v in pipeline.values())

    # headline floor per width (best pallas_step wall/step across variants)
    # — the quantity benchmarks.floor_guard regression-checks in CI
    floor_walls = {
        width: min(walls.values()) for width, walls in sweep.items() if walls
    }

    strictly_lower = bool(ratios) and all(v < 1.0 for v in ratios.values())
    # one uniform pass: smoke artifacts null every timing verdict (see
    # the verdict comment above); the full run records them as computed
    (strictly_lower_v, butterfly_ok_v, monotone_v, amortization_ok_v,
     pipeline_ok_v) = (
        (None,) * 5 if smoke
        else (strictly_lower, butterfly_ok, monotone, amortization_ok,
              pipeline_ok))
    stem = "pallas_floor_smoke" if smoke else "pallas_floor"
    path_csv = write_csv(
        f"{stem}.csv",
        ["backend", "variant", "width", "grain", "steps", "wall_s",
         "wall_per_step_s", "granularity_us", "dispatches"],
        rows_out,
    )
    path_json = bench_path(f"{stem}.json")
    with open(path_json, "w") as f:
        json.dump({
            "devices": devices, "sweep_devices": sweep_devices,
            "steps": steps, "payload": payload,
            "grain_iterations": list(cfg.grains),
            "smoke": smoke,
            "calibrated": calibrate,
            "cost_model": cost_model,
            "pallas_over_fused_per_step": ratios,
            "pallas_step_strictly_lower": strictly_lower_v,
            "butterfly_patterns": list(butterfly_patterns),
            "butterfly_over_fused_per_step": butterfly,
            "butterfly_at_or_below_fused": butterfly_ok_v,
            "butterfly_floor_wall_per_step": butterfly_floor,
            "steps_per_launch_values": list(sweep_s),
            "steps_per_launch_sweep": sweep,
            "s1_over_s8_speedup": s8_speedups,
            "sweep_monotone_nonincreasing": monotone_v,
            "amortization_ok_s8_1p5x": amortization_ok_v,
            "floor_wall_per_step": floor_walls,
            "pipeline_at_tuned_s": pipeline,
            "pipeline_ok_0p85": pipeline_ok_v,
        }, f, indent=2)
    if verbose:
        print(f"pallas_step strictly lower wall/step than fused: "
              f"{strictly_lower}")
        if butterfly:
            print("butterfly wall/step at or below fused: "
                  f"{butterfly_ok} ("
                  + ", ".join(f"{p} W={w}: {v:.3f}"
                              for p, by in sorted(butterfly.items())
                              for w, v in sorted(by.items(),
                                                 key=lambda kv: int(kv[0])))
                  + ")")
        if sweep:
            print(f"steps_per_launch sweep monotone: {monotone}; "
                  f"S1/S8 speedups: "
                  + ", ".join(f"W={w}: {v:.2f}x"
                              for w, v in sorted(s8_speedups.items(),
                                                 key=lambda kv: int(kv[0]))))
        if pipeline:
            print("pipeline <= 0.85x ablation at tuned S: "
                  f"{pipeline_ok} ("
                  + ", ".join(f"W={w}: {v['pipe_over_nopipe']:.3f}"
                              for w, v in sorted(pipeline.items(),
                                                 key=lambda kv: int(kv[0])))
                  + ")")
        print(f"wrote {path_csv} and {path_json}")
    return {"ratios": ratios, "strictly_lower": strictly_lower,
            "butterfly": butterfly, "butterfly_ok": butterfly_ok,
            "sweep": sweep, "monotone": monotone,
            "s8_speedups": s8_speedups, "amortization_ok": amortization_ok,
            "pipeline": pipeline, "pipeline_ok": pipeline_ok}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--steps", type=int, default=0,
                    help="override the floor preset's step count")
    ap.add_argument("--reps", type=int, default=0)
    ap.add_argument("--widths", default=",".join(str(w) for w in WIDTHS))
    ap.add_argument("--sweep-widths",
                    default=",".join(str(w) for w in SWEEP_WIDTHS),
                    help="widths for the steps_per_launch sweep")
    ap.add_argument("--sweep-s", default=",".join(str(s) for s in SWEEP_S),
                    help="steps_per_launch depths to sweep")
    ap.add_argument("--sweep-devices", type=int, default=SWEEP_DEVICES,
                    help="device count for the steps_per_launch sweep "
                         "(multi-device: the per-step collective is the "
                         "cost blocking amortizes)")
    ap.add_argument("--pipe-widths",
                    default=",".join(str(w) for w in PIPE_WIDTHS),
                    help="widths for the pipeline-vs-ablation pair")
    ap.add_argument("--butterfly-widths",
                    default=",".join(str(w) for w in BUTTERFLY_WIDTHS),
                    help="widths for the fft/tree butterfly floor rows")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-long CI guard: tiny sweep, no assertions, "
                         "writes pallas_floor_smoke.* (committed artifacts "
                         "untouched)")
    ap.add_argument("--calibrate", action="store_true",
                    help="run the cost-model probes first (merged into "
                         "artifacts/bench/cost_model.json) so the sweeps' "
                         "'auto' picks resolve through measured costs; the "
                         "snapshot is recorded in the artifact JSON")
    backend_options_args(ap)
    a = ap.parse_args(argv)
    opts = parse_backend_options(a)
    if a.smoke:
        # reps=3 (not 1): the floor guard compares this run's best-of
        # against the committed baseline, and a single rep on a shared
        # runner is all jitter
        res = run(devices=a.devices, steps=17, reps=3, widths=(64,),
                  sweep_widths=(64,), sweep_s=(1, 2, 4, 8),
                  sweep_devices=2, pipe_widths=(256,),
                  butterfly_widths=(64,), options=opts,
                  smoke=True, calibrate=a.calibrate)
        # the smoke run guards the CODE PATHS (blocked kernel, deep
        # exchange, pipelined phase split, butterfly stride plan, artifact
        # schema), not the timing verdicts — but every swept width must
        # have actually produced variant rows (a width whose variants were
        # all skipped means the blocked path never ran), the pipeline pair
        # must have run both labels, and every butterfly pattern must have
        # produced its fused/pallas_step row pair
        ok = bool(res["sweep"]) and all(res["sweep"].values())
        ok = ok and bool(res["pipeline"]) and all(
            set(v) >= {"pipe_wall_per_step", "nopipe_wall_per_step"}
            for v in res["pipeline"].values())
        ok = ok and set(res["butterfly"]) == set(BUTTERFLY_PATTERNS) and all(
            res["butterfly"].values())
        return 0 if ok else 1
    run(devices=a.devices, steps=a.steps, reps=a.reps,
        widths=tuple(int(w) for w in a.widths.split(",")),
        sweep_widths=tuple(int(w) for w in a.sweep_widths.split(",")),
        sweep_s=tuple(int(s) for s in a.sweep_s.split(",")),
        sweep_devices=a.sweep_devices,
        pipe_widths=tuple(int(w) for w in a.pipe_widths.split(",")),
        butterfly_widths=tuple(
            int(w) for w in a.butterfly_widths.split(",")),
        options=opts, calibrate=a.calibrate)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
