"""CI floor-regression guard for the pallas_step smoke benchmark.

Compares a freshly produced ``pallas_floor_smoke.json`` (written by
``python -m benchmarks.pallas_floor --smoke``) against the committed
baseline ``pallas_floor_smoke_baseline.json`` and fails when the smoke
run's headline floor — best pallas_step wall/step per width, the
``floor_wall_per_step`` field — regresses by more than ``--factor``
(default 2x).

Cross-machine wall-clock comparisons are inherently shaky (the baseline
was produced on the dev container; shared CI runners drift), so an
absolute regression alone does not fail the guard: it must coincide with
the smoke run's own IN-RUN amortization signal collapsing —
``s1_over_s8_speedup`` dropping below ``--min-amortization`` (default
1.05x — a degraded fast path measures ~1.0x, a healthy noisy run 1.3-9x). The failure mode this guard exists for (the blocked/pipelined fast
path silently degrading to per-step dispatch — the tuner collapsing to
S=1, the pipeline gating itself off into a slow path, an accidental
per-step dispatch) produces exactly that signature: wall/step jumps 5-30x
AND deep launches stop beating S=1, both far outside runner variance. A
uniformly slow runner keeps the in-run ratio healthy and only warns.
Widths present in only one file are reported but not judged.
"""
from __future__ import annotations

import argparse
import json
import sys


def check_butterfly(current: dict, baseline: dict, factor: float) -> list:
    """Butterfly-floor guard: same two-signal rule, with the in-run health
    signal being the run's own pallas/fused ratio — the stride plan
    degrading (e.g. falling back to per-op dispatch) pushes pallas_step
    ABOVE fused in the same process, which runner slowness cannot."""
    failures = []
    cur = current.get("butterfly_floor_wall_per_step", {})
    base = baseline.get("butterfly_floor_wall_per_step", {})
    ratios = current.get("butterfly_over_fused_per_step", {})
    if not base:
        # baselines that predate the butterfly rows carry no keys: nothing
        # to guard (regenerating the baseline arms this check)
        return failures
    judged = 0
    for key, b in sorted(base.items()):
        c = cur.get(key)
        if c is None:
            print(f"floor_guard: butterfly {key} missing from current run "
                  f"(not judged)")
            continue
        judged += 1
        pattern, width = key.split("@")
        in_run = ratios.get(pattern, {}).get(width)
        ratio = c / b
        regressed = ratio > factor
        unhealthy = in_run is not None and in_run > 1.0
        if regressed and unhealthy:
            verdict = "REGRESSED"
            failures.append(
                f"butterfly {key}: {c*1e6:.2f} us/step is {ratio:.2f}x the "
                f"baseline {b*1e6:.2f} us/step (limit {factor}x) AND "
                f"pallas_step fell above fused in-run ({in_run:.2f}x) — "
                f"the stride plan degraded, not the runner")
        elif regressed:
            verdict = "SLOW-RUNNER? (absolute regression, in-run signal healthy)"
        else:
            verdict = "OK"
        in_run_txt = (f", pallas/fused {in_run:.2f}x"
                      if in_run is not None else "")
        print(f"floor_guard: butterfly {key}: baseline {b*1e6:.2f} us/step, "
              f"current {c*1e6:.2f} us/step ({ratio:.2f}x{in_run_txt}) "
              f"{verdict}")
    if judged == 0:
        failures.append(
            "baseline has butterfly floors but the current run judged "
            "none of them (butterfly rows missing or key schema drifted)")
    return failures


def check(current: dict, baseline: dict, factor: float,
          min_amortization: float) -> list:
    """Returns a list of human-readable failures (empty = pass)."""
    failures = []
    cur = current.get("floor_wall_per_step", {})
    base = baseline.get("floor_wall_per_step", {})
    speedups = current.get("s1_over_s8_speedup", {})
    if not base:
        failures.append("baseline has no floor_wall_per_step field")
        return failures
    judged = 0
    for width, b in sorted(base.items(), key=lambda kv: int(kv[0])):
        c = cur.get(width)
        if c is None:
            print(f"floor_guard: width {width} missing from current run "
                  f"(not judged)")
            continue
        judged += 1
        ratio = c / b
        amort = speedups.get(width)
        regressed = ratio > factor
        collapsed = amort is not None and amort < min_amortization
        if regressed and collapsed:
            verdict = "REGRESSED"
            failures.append(
                f"width {width}: {c*1e6:.2f} us/step is {ratio:.2f}x the "
                f"baseline {b*1e6:.2f} us/step (limit {factor}x) AND the "
                f"in-run S1/S8 amortization collapsed to {amort:.2f}x "
                f"(floor {min_amortization}x) — the blocked fast path "
                f"degraded, not the runner")
        elif regressed:
            verdict = "SLOW-RUNNER? (absolute regression, in-run signal healthy)"
        else:
            verdict = "OK"
        amort_txt = f", S1/S8 {amort:.2f}x" if amort is not None else ""
        print(f"floor_guard: W={width}: baseline {b*1e6:.2f} us/step, "
              f"current {c*1e6:.2f} us/step ({ratio:.2f}x{amort_txt}) "
              f"{verdict}")
    if judged == 0:
        failures.append("no width was present in both files")
    failures.extend(check_butterfly(current, baseline, factor))
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current",
                    default="artifacts/bench/pallas_floor_smoke.json")
    ap.add_argument("--baseline",
                    default="artifacts/bench/pallas_floor_smoke_baseline.json")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="max allowed current/baseline wall-per-step ratio")
    ap.add_argument("--min-amortization", type=float, default=1.05,
                    help="in-run S1/S8 speedup below which an absolute "
                         "regression counts as a fast-path failure")
    a = ap.parse_args(argv)
    with open(a.current) as f:
        current = json.load(f)
    with open(a.baseline) as f:
        baseline = json.load(f)
    failures = check(current, baseline, a.factor, a.min_amortization)
    for msg in failures:
        print(f"floor_guard: FAIL: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
