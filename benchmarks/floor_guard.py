"""CI regression suite for the pallas_step smoke benchmark (reframe-style).

Earlier revisions hard-coded ONE rule (wall-per-step ratio vs a committed
baseline). This is now a parameterized suite in the style of a ReFrame
test battery: every check is a :class:`PerfCheck` with

  sanity    preconditions on the artifact (field present, value finite and
            positive) — a malformed run FAILS rather than silently passing;
  perf      the measured value judged against a per-system REFERENCE value
            within an allowed factor;
  health    an optional IN-RUN signal that distinguishes "the fast path
            degraded" from "the runner is slow".

Cross-machine wall-clock comparisons are inherently shaky (the committed
baseline was produced on the dev container; shared CI runners drift), so
an absolute regression alone never fails a check: it must coincide with
the run's own health signal collapsing. The failure mode this guard
exists for — the blocked/pipelined fast path silently degrading to
per-step dispatch (tuner collapsing to S=1, pipeline gating itself off,
an accidental per-step dispatch) — produces exactly that signature:
wall/step jumps 5-30x AND deep launches stop beating S=1 (or, for the
butterfly rows, pallas_step falls above fused in the same process), both
far outside runner variance. A uniformly slow runner keeps the in-run
signals healthy and only WARNs.

Per-system reference values: by default each check's reference is the
committed baseline's measured value, but the baseline JSON may carry a
``"references"`` object overriding reference and/or factor per check
name::

    "references": {"floor@64": {"reference": 5.0e-05, "factor": 3.0}}

so a platform with known-different floors tunes individual checks without
touching the guard. The optional ``--cost-model`` file (written by the CI
calibration step, ``python -m repro.kernels.probes --smoke``) adds sanity
checks over the measured CostModel — schema loads, probed costs positive
and finite — so a broken calibration fails CI before it silently steers
every "auto" schedule; a missing file SKIPs (local runs stay green).

The optional ``--trace`` file (written by benchmarks/overhead_decomposition)
arms the TRACE-FED health leg: instead of re-deriving an overlap signal
from walls, the span trace's own verdict (hidden exchange fraction) and
exchange share of wall are judged directly — ``--smoke`` points it at the
smoke artifact with a presence/sanity bound (tiny smoke shapes cannot
hide their exchange; full runs default to requiring >50% hidden).

Exit status: 1 iff any check FAILs. Checks found in only one artifact are
reported and SKIPped, never judged.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import sys
from typing import Callable, Dict, List, Optional

OK, WARN, FAIL, SKIP = "OK", "WARN", "FAIL", "SKIP"


def _us(v: float) -> str:
    return f"{v * 1e6:.2f} us/step"


@dataclasses.dataclass
class PerfCheck:
    """One parameterized check: sanity + perf-vs-reference + health.

    ``health_bad`` returns True when the in-run signal says the fast path
    itself degraded (not the runner); with no health signal available an
    absolute regression stays a WARN — same conservatism as always.
    """

    name: str
    value: Optional[float]
    reference: Optional[float]
    factor: float
    fmt: Callable[[float], str] = _us
    health_desc: str = ""
    health_value: Optional[float] = None
    health_bad: Optional[Callable[[float], bool]] = None
    sanity_errors: List[str] = dataclasses.field(default_factory=list)

    def evaluate(self) -> "CheckResult":
        if self.sanity_errors:
            return CheckResult(self.name, FAIL,
                               "sanity: " + "; ".join(self.sanity_errors))
        if self.value is None and self.reference is None:
            return CheckResult(self.name, OK, "sanity checks passed")
        if self.value is None:
            return CheckResult(self.name, SKIP,
                               "missing from current run (not judged)")
        if self.reference is None:
            return CheckResult(self.name, SKIP,
                               "no reference value (not judged)")
        ratio = self.value / self.reference
        detail = (f"reference {self.fmt(self.reference)}, current "
                  f"{self.fmt(self.value)} ({ratio:.2f}x, limit "
                  f"{self.factor:g}x)")
        if self.health_value is not None:
            detail += f", {self.health_desc}={self.health_value:.2f}"
        if ratio <= self.factor:
            return CheckResult(self.name, OK, detail)
        unhealthy = (self.health_bad is not None
                     and self.health_value is not None
                     and self.health_bad(self.health_value))
        if unhealthy:
            return CheckResult(
                self.name, FAIL,
                detail + " AND the in-run health signal collapsed — the "
                "fast path degraded, not the runner")
        return CheckResult(
            self.name, WARN,
            detail + " — SLOW-RUNNER? (absolute regression, in-run "
            "signal healthy)")


@dataclasses.dataclass
class CheckResult:
    name: str
    status: str
    message: str

    def line(self) -> str:
        return f"floor_guard: {self.name}: {self.message} [{self.status}]"


def _reference_for(baseline: dict, name: str, measured: Optional[float],
                   default_factor: float):
    """(reference, factor) for one check: the committed baseline's measured
    value unless its "references" object pins a per-system override."""
    override = baseline.get("references", {}).get(name, {})
    ref = override.get("reference", measured)
    factor = float(override.get("factor", default_factor))
    return ref, factor


def _sane_positive(name: str, value) -> List[str]:
    if value is None:
        return []  # absence is SKIP territory, not a sanity failure
    try:
        v = float(value)
    except (TypeError, ValueError):
        return [f"{name} is not a number: {value!r}"]
    if not math.isfinite(v) or v <= 0:
        return [f"{name} must be finite and positive, got {v!r}"]
    return []


def floor_checks(current: dict, baseline: dict, factor: float,
                 min_amortization: float) -> List[PerfCheck]:
    """Per-width headline-floor checks; health = the run's own S1/S8
    amortization (a degraded fast path measures ~1.0x, a healthy noisy
    run 1.3-9x)."""
    checks: List[PerfCheck] = []
    cur = current.get("floor_wall_per_step", {})
    base = baseline.get("floor_wall_per_step", {})
    speedups = current.get("s1_over_s8_speedup", {})
    for width, b in sorted(base.items(), key=lambda kv: int(kv[0])):
        name = f"floor@{width}"
        value = cur.get(width)
        ref, fac = _reference_for(baseline, name, b, factor)
        amort = speedups.get(width)
        checks.append(PerfCheck(
            name=name, value=value, reference=ref, factor=fac,
            health_desc="S1/S8", health_value=amort,
            health_bad=lambda a, lo=min_amortization: a < lo,
            sanity_errors=_sane_positive(name, value),
        ))
    return checks


def butterfly_checks(current: dict, baseline: dict,
                     factor: float) -> List[PerfCheck]:
    """Butterfly (stride-plan) floor checks; health = the run's own
    pallas/fused ratio — the stride plan degrading pushes pallas_step
    ABOVE fused in the same process, which runner slowness cannot."""
    checks: List[PerfCheck] = []
    cur = current.get("butterfly_floor_wall_per_step", {})
    base = baseline.get("butterfly_floor_wall_per_step", {})
    ratios = current.get("butterfly_over_fused_per_step", {})
    for key, b in sorted(base.items()):
        name = f"butterfly@{key}"
        pattern, width = key.split("@")
        value = cur.get(key)
        ref, fac = _reference_for(baseline, name, b, factor)
        in_run = ratios.get(pattern, {}).get(width)
        checks.append(PerfCheck(
            name=name, value=value, reference=ref, factor=fac,
            health_desc="pallas/fused", health_value=in_run,
            health_bad=lambda r: r > 1.0,
            sanity_errors=_sane_positive(name, value),
        ))
    return checks


def cost_model_checks(model_file: dict) -> List[PerfCheck]:
    """Sanity-only checks over the CI calibration artifact: every probed
    cost must be finite and positive (perf bounds don't apply — the model
    is measured fresh per runner; what must never happen is a garbage
    calibration silently steering every "auto" schedule)."""
    checks: List[PerfCheck] = []
    entries = model_file.get("entries", {})
    if not isinstance(entries, dict) or not entries:
        return [PerfCheck(name="cost_model", value=None, reference=None,
                          factor=1.0,
                          sanity_errors=["calibration file has no entries"])]
    for key, m in sorted(entries.items()):
        errors: List[str] = []
        for field in ("exchange_row_steps", "launch_us", "row_step_us"):
            errors += _sane_positive(field, m.get(field, None))
            if m.get(field) is None:
                errors.append(f"{field} missing")
        for group in ("halo_exchange_us", "stride_exchange_us", "gather_us"):
            for k, v in (m.get(group) or {}).items():
                errors += _sane_positive(f"{group}[{k}]", v)
        if m.get("source") != "measured":
            errors.append(f"source is {m.get('source')!r}, not 'measured'")
        checks.append(PerfCheck(
            name=f"cost_model[{key}]", value=None, reference=None,
            factor=1.0, sanity_errors=errors))
        if not errors:
            # a sane model SKIPs the perf leg by construction (no
            # reference); surface the calibration in the CI log instead
            print(f"floor_guard: cost_model[{key}]: exchange="
                  f"{float(m['exchange_row_steps']):.0f} row-steps, "
                  f"launch={m['launch_us']:.1f}us, "
                  f"row-step={m['row_step_us']:.4f}us")
    return checks


def trace_checks(trace_art: dict, *, max_visible: float,
                 max_exchange_fraction: float) -> List[PerfCheck]:
    """Trace-fed health leg over the overhead_decomposition artifact.

    Replaces a re-derived signal with what the span trace DIRECTLY
    measured: ``trace@schema`` is the sanity half (artifact schema, a
    well-formed overlap verdict), ``trace@overlap`` the perf half — the
    VISIBLE exchange fraction (1 - hidden_fraction) judged against an
    ideal reference of full hiding, with the run's own exchange share of
    total wall as the health signal. The same two-signal rule as every
    other check: a shortfall in hiding only FAILs when exchange also
    dominates the wall (the pipeline broke AND it matters); a shortfall
    over a wall that exchange barely touches stays a WARN."""
    errors: List[str] = []
    if trace_art.get("schema") != 1:
        errors.append(
            f"trace artifact schema {trace_art.get('schema')!r}, expected 1")
    ov = trace_art.get("pallas_overlap") or {}
    verdict = ov.get("verdict")
    if verdict not in ("hidden", "visible", "unavailable", None):
        errors.append(f"unknown overlap verdict {verdict!r}")
    hidden = ov.get("hidden_fraction")
    if hidden is not None and not (0.0 <= float(hidden) <= 1.0):
        errors.append(f"hidden_fraction out of [0, 1]: {hidden!r}")
    checks = [PerfCheck(name="trace@schema", value=None, reference=None,
                        factor=1.0, sanity_errors=errors)]
    visible = None if hidden is None else max(1.0 - float(hidden), 1e-9)
    checks.append(PerfCheck(
        name="trace@overlap", value=visible, reference=1.0,
        factor=max_visible,
        fmt=lambda v: f"{v * 100:.0f}% exchange visible",
        health_desc="exchange_fraction",
        health_value=trace_art.get("pallas_exchange_fraction"),
        health_bad=lambda f, hi=max_exchange_fraction: f > hi,
    ))
    return checks


def chaos_checks(chaos_art: dict, *, max_recovery_tax: float,
                 max_armor_tax: float) -> List[PerfCheck]:
    """Resilience leg over the benchmarks/chaos artifact.

    ``chaos@schema`` is the sanity half (artifact schema, rows judged,
    verdict present); ``chaos@identity`` fails outright when any chaos row
    lost bit-identical recovery — that IS the in-run correctness signal,
    and a correctness loss is never a slow-runner artifact. The per-class
    ``chaos@tax:*`` checks then apply the standard two-signal rule to the
    recovery tax: tax past the bound with bit-identity intact is a WARN
    (loaded runner stretching the backoff sleeps); tax past the bound with
    identity broken FAILs. ``chaos@armor`` bounds what the resilient
    executor costs with no faults at all (the zero-cost contract on the
    clean path)."""
    errors: List[str] = []
    if chaos_art.get("schema") != SCHEMA_CHAOS:
        errors.append(
            f"chaos artifact schema {chaos_art.get('schema')!r}, "
            f"expected {SCHEMA_CHAOS}")
    verdict = chaos_art.get("verdict") or {}
    judged = [r for r in chaos_art.get("rows", []) if "skip" not in r]
    if not judged:
        errors.append("chaos artifact judged no rows")
    if "recovery_bit_identical" not in verdict:
        errors.append("verdict missing recovery_bit_identical")
    checks = [PerfCheck(name="chaos@schema", value=None, reference=None,
                        factor=1.0, sanity_errors=errors)]
    identity_errors = [] if verdict.get("recovery_bit_identical", True) \
        else ["a faulted run was NOT bit-identical after recovery"]
    checks.append(PerfCheck(name="chaos@identity", value=None,
                            reference=None, factor=1.0,
                            sanity_errors=identity_errors))
    fmt = lambda v: f"{v:.2f}x tax"  # noqa: E731
    for cls, summary in sorted((verdict.get("per_class") or {}).items()):
        if cls == "straggler":
            # the straggler row's wall carries a deliberate stall sized to
            # the run (a detection row, not a recovery row): its tax is
            # ~3x by construction and proves nothing about recovery cost
            continue
        health = 1.0 if summary.get("bit_identical") else 0.0
        checks.append(PerfCheck(
            name=f"chaos@tax:{cls}",
            value=summary.get("max_recovery_tax"), reference=1.0,
            factor=max_recovery_tax, fmt=fmt,
            health_desc="bit_identical", health_value=health,
            health_bad=lambda h: h < 1.0,
            sanity_errors=_sane_positive(
                f"chaos@tax:{cls}", summary.get("max_recovery_tax")),
        ))
    identity_health = 1.0 if verdict.get("recovery_bit_identical") else 0.0
    checks.append(PerfCheck(
        name="chaos@armor", value=verdict.get("max_armor_tax"),
        reference=1.0, factor=max_armor_tax, fmt=fmt,
        health_desc="bit_identical", health_value=identity_health,
        health_bad=lambda h: h < 1.0,
        sanity_errors=_sane_positive("chaos@armor",
                                     verdict.get("max_armor_tax")),
    ))
    return checks


SCHEMA_CHAOS = 1


def scaling_checks(scaling_art: dict, scaling_base: dict, factor: float, *,
                   max_pallas_over_bsp: float,
                   min_gather_speedup: float) -> List[PerfCheck]:
    """Scaling leg over the fig2_scaling artifact (weak/strong sweeps).

    ``scaling@schema`` is the sanity half: the guard block exists and its
    efficiencies are in range. ``scaling@weak`` judges the weak-scaling
    OVERHEAD GROWTH at the guard device count — 1/efficiency, lower is
    better, so the standard ratio-vs-reference machinery applies — against
    the committed baseline, with the run's OWN pallas/bsp wall-per-task
    ratio at the same D as the health signal: the megakernel pricing tasks
    like per-step-dispatch bsp in the same process is a fast-path
    collapse, which runner slowness cannot produce (both walls stretch
    together). ``scaling@gather`` bounds the chunked-vs-monolithic gather
    ablation at D >= 16: the walls come from ONE worker process, so the
    ratio is already machine-independent and the health signal is the
    speedup itself. Smoke artifacts cap at D=8 and carry no 16+ ablation —
    that check SKIPs, the weak check still judges at the smoke guard D.
    A baseline produced at a different guard D yields no reference
    (SKIP): efficiency at D=8 says nothing about the D=16 bar.
    """
    errors: List[str] = []
    guard = scaling_art.get("guard") or {}
    if not guard:
        errors.append("scaling artifact has no guard block")
    eff = guard.get("weak_efficiency")
    if eff is not None and not (0.0 < float(eff) <= 2.0):
        errors.append(f"weak_efficiency out of (0, 2]: {eff!r}")
    errors += _sane_positive("guard_devices", guard.get("guard_devices"))
    checks = [PerfCheck(name="scaling@schema", value=None, reference=None,
                        factor=1.0, sanity_errors=errors)]

    base_guard = scaling_base.get("guard") or {}
    value = None if eff is None else 1.0 / max(float(eff), 1e-9)
    base_eff = base_guard.get("weak_efficiency")
    measured_ref = None
    if (base_eff is not None
            and base_guard.get("guard_devices") == guard.get("guard_devices")):
        measured_ref = 1.0 / max(float(base_eff), 1e-9)
    weak_name = f"scaling@weak:D{guard.get('guard_devices', '?')}"
    ref, fac = _reference_for(scaling_base, weak_name, measured_ref, factor)
    pallas = guard.get("pallas_wall_per_task_us")
    bsp = guard.get("bsp_wall_per_task_us")
    in_run = None
    if pallas is not None and bsp:
        in_run = float(pallas) / float(bsp)
    checks.append(PerfCheck(
        name=weak_name, value=value, reference=ref, factor=fac,
        fmt=lambda v: f"{v:.2f}x overhead growth",
        health_desc="pallas/bsp", health_value=in_run,
        health_bad=lambda r, hi=max_pallas_over_bsp: r > hi,
        sanity_errors=_sane_positive("weak overhead growth", value),
    ))

    speedup = guard.get("chunked_speedup_at_16plus")
    checks.append(PerfCheck(
        name="scaling@gather",
        value=None if speedup is None else 1.0 / max(float(speedup), 1e-9),
        reference=1.0, factor=1.0 / min_gather_speedup,
        fmt=lambda v: f"chunked at {1.0 / v:.2f}x vs monolithic",
        health_desc="in-run speedup", health_value=speedup,
        health_bad=lambda s, lo=min_gather_speedup: s < lo,
    ))
    return checks


SCHEMA_SERVE = 1


def serve_checks(serve_art: dict, serve_base: dict, factor: float, *,
                 min_slot_utilization: float = 0.5) -> List[PerfCheck]:
    """Serving leg over the benchmarks/serve_taskbench artifact.

    ``serve@schema`` is the sanity half; ``serve@identity`` fails outright
    when any served request lost bit-identity against its serial oracle —
    correctness, never a slow-runner artifact. ``serve@churn`` likewise
    fails outright when the continuous-batching contract degraded: no
    stacked cohort changed membership >= 2 times with zero recompiles, or
    the packer collapsed the mixed stream below two stacked cohorts —
    both are structural properties of the fabric, independent of runner
    speed. The per-K ``serve@p99:*`` checks then apply the standard
    two-signal rule to tail latency vs the committed baseline: a p99
    regression alone WARNs (loaded runner stretches every wall); it FAILs
    only when that row's in-run slot utilization ALSO cratered — idle
    slots with slow requests mean admission/packing broke, which runner
    slowness cannot produce (a slow runner keeps slots exactly as busy)."""
    errors: List[str] = []
    if serve_art.get("schema") != SCHEMA_SERVE:
        errors.append(
            f"serve artifact schema {serve_art.get('schema')!r}, "
            f"expected {SCHEMA_SERVE}")
    verdict = serve_art.get("verdict") or {}
    rows = [r for r in serve_art.get("rows", []) if "skip" not in r]
    if not rows:
        errors.append("serve artifact judged no rows")
    for key in ("bit_identical", "dynamic_cohort", "min_stacked_cohorts"):
        if key not in verdict:
            errors.append(f"verdict missing {key}")
    checks = [PerfCheck(name="serve@schema", value=None, reference=None,
                        factor=1.0, sanity_errors=errors)]
    identity_errors = [] if verdict.get("bit_identical", True) \
        else ["a served request was NOT bit-identical to its serial oracle"]
    checks.append(PerfCheck(name="serve@identity", value=None,
                            reference=None, factor=1.0,
                            sanity_errors=identity_errors))
    churn_errors = []
    if not verdict.get("dynamic_cohort", True):
        churn_errors.append(
            "no stacked cohort churned membership >= 2 times without a "
            "recompile (continuous batching degraded to static cohorts)")
    if verdict.get("min_stacked_cohorts", 2) < 2:
        churn_errors.append(
            "mixed request stream produced < 2 stacked cohorts (packer "
            "collapsed compatibility classes)")
    checks.append(PerfCheck(name="serve@churn", value=None, reference=None,
                            factor=1.0, sanity_errors=churn_errors))
    base_p99 = (serve_base.get("verdict") or {}).get("p99_ms_by_slots", {})
    fmt = lambda v: f"{v:.1f} ms p99"  # noqa: E731
    for row in rows:
        k = str(row.get("slots"))
        name = f"serve@p99:K{k}"
        ref, fac = _reference_for(serve_base, name, base_p99.get(k), factor)
        checks.append(PerfCheck(
            name=name, value=row.get("p99_ms"), reference=ref, factor=fac,
            fmt=fmt,
            health_desc="slot_utilization",
            health_value=row.get("slot_utilization"),
            health_bad=lambda u, lo=min_slot_utilization: u < lo,
            sanity_errors=_sane_positive(name, row.get("p99_ms")),
        ))
    return checks


def build_suite(current: dict, baseline: dict, factor: float,
                min_amortization: float,
                cost_model: Optional[dict] = None,
                trace_art: Optional[dict] = None,
                max_visible: float = 1.0,
                max_exchange_fraction: float = 0.6,
                chaos_art: Optional[dict] = None,
                max_recovery_tax: float = 2.5,
                max_armor_tax: float = 3.0,
                scaling_art: Optional[dict] = None,
                scaling_base: Optional[dict] = None,
                max_pallas_over_bsp: float = 1.5,
                min_gather_speedup: float = 0.9,
                serve_art: Optional[dict] = None,
                serve_base: Optional[dict] = None,
                min_slot_utilization: float = 0.5) -> List[PerfCheck]:
    checks = floor_checks(current, baseline, factor, min_amortization)
    checks += butterfly_checks(current, baseline, factor)
    if cost_model is not None:
        checks += cost_model_checks(cost_model)
    if trace_art is not None:
        checks += trace_checks(trace_art, max_visible=max_visible,
                               max_exchange_fraction=max_exchange_fraction)
    if chaos_art is not None:
        checks += chaos_checks(chaos_art, max_recovery_tax=max_recovery_tax,
                               max_armor_tax=max_armor_tax)
    if scaling_art is not None:
        checks += scaling_checks(scaling_art, scaling_base or {}, factor,
                                 max_pallas_over_bsp=max_pallas_over_bsp,
                                 min_gather_speedup=min_gather_speedup)
    if serve_art is not None:
        checks += serve_checks(serve_art, serve_base or {}, factor,
                               min_slot_utilization=min_slot_utilization)
    return checks


def run_suite(checks: List[PerfCheck],
              families: Dict[str, int]) -> List[str]:
    """Evaluate every check, print the table, return FAIL messages.

    ``families`` maps a check-name prefix to the minimum number of JUDGED
    (non-SKIP) checks the suite must contain for it — a baseline full of
    floors that the current run judged none of is itself a failure
    (schema drift / rows silently missing), the "sanity" half of the
    reframe contract applied to the suite as a whole."""
    failures: List[str] = []
    judged: Dict[str, int] = {k: 0 for k in families}
    for c in checks:
        res = c.evaluate()
        print(res.line())
        if res.status == FAIL:
            failures.append(f"{res.name}: {res.message}")
        if res.status not in (SKIP,):
            for prefix in families:
                if res.name.startswith(prefix):
                    judged[prefix] += 1
    for prefix, minimum in families.items():
        if judged[prefix] < minimum:
            failures.append(
                f"suite judged {judged[prefix]} {prefix}* checks, needs "
                f">= {minimum} (rows missing or key schema drifted)")
    return failures


def check(current: dict, baseline: dict, factor: float,
          min_amortization: float,
          cost_model: Optional[dict] = None,
          trace_art: Optional[dict] = None,
          max_visible: float = 1.0,
          max_exchange_fraction: float = 0.6,
          chaos_art: Optional[dict] = None,
          max_recovery_tax: float = 2.5,
          max_armor_tax: float = 3.0,
          scaling_art: Optional[dict] = None,
          scaling_base: Optional[dict] = None,
          max_pallas_over_bsp: float = 1.5,
          min_gather_speedup: float = 0.9,
          serve_art: Optional[dict] = None,
          serve_base: Optional[dict] = None,
          min_slot_utilization: float = 0.5) -> list:
    """Returns a list of human-readable failures (empty = pass)."""
    base = baseline.get("floor_wall_per_step", {})
    if not base:
        return ["baseline has no floor_wall_per_step field"]
    families = {"floor@": 1}
    if baseline.get("butterfly_floor_wall_per_step"):
        # baselines that predate the butterfly rows carry no keys: nothing
        # to guard (regenerating the baseline arms this family)
        families["butterfly@"] = 1
    if trace_art is not None:
        families["trace@"] = 1
    if chaos_art is not None:
        families["chaos@"] = 2
    if scaling_art is not None:
        families["scaling@"] = 1
    if serve_art is not None:
        # schema + identity + churn always judge; p99 rows may SKIP when
        # the committed baseline predates a new K sweep
        families["serve@"] = 3
    suite = build_suite(current, baseline, factor, min_amortization,
                        cost_model, trace_art, max_visible,
                        max_exchange_fraction, chaos_art,
                        max_recovery_tax, max_armor_tax,
                        scaling_art, scaling_base,
                        max_pallas_over_bsp, min_gather_speedup,
                        serve_art, serve_base, min_slot_utilization)
    return run_suite(suite, families)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current",
                    default="artifacts/bench/pallas_floor_smoke.json")
    ap.add_argument("--baseline",
                    default="artifacts/bench/pallas_floor_smoke_baseline.json")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="default max current/reference ratio (per-check "
                         "overrides live in the baseline's 'references')")
    ap.add_argument("--min-amortization", type=float, default=1.05,
                    help="in-run S1/S8 speedup below which an absolute "
                         "regression counts as a fast-path failure")
    ap.add_argument("--cost-model", default=None,
                    help="CI calibration artifact to sanity-check "
                         "(missing file = skip, stays green locally)")
    ap.add_argument("--trace", default=None,
                    help="overhead_decomposition artifact feeding the "
                         "trace health leg (missing file = skip)")
    ap.add_argument("--smoke", action="store_true",
                    help="smoke defaults: --trace points at the smoke "
                         "decomposition artifact, and the overlap bound "
                         "relaxes to presence/sanity only (tiny smoke "
                         "shapes cannot hide their exchange)")
    ap.add_argument("--max-visible", type=float, default=None,
                    help="visible exchange fraction above which the "
                         "overlap check regresses (default 0.5, i.e. the "
                         "pipeline must hide >50%%; 1.0 under --smoke)")
    ap.add_argument("--max-exchange-fraction", type=float, default=0.6,
                    help="in-run health bound: exchange share of total "
                         "wall above which an overlap shortfall FAILs")
    ap.add_argument("--chaos", default=None, nargs="?",
                    const="artifacts/bench/chaos.json",
                    help="benchmarks/chaos artifact feeding the resilience "
                         "leg (flag alone uses the default path; missing "
                         "file = skip)")
    ap.add_argument("--max-recovery-tax", type=float, default=2.5,
                    help="faulted/clean resilient wall ratio above which "
                         "a chaos tax check regresses (two-signal: WARN "
                         "unless bit-identity also broke)")
    ap.add_argument("--max-armor-tax", type=float, default=3.0,
                    help="no-fault resilient/production wall ratio bound "
                         "(the clean-path cost of the armor)")
    ap.add_argument("--scaling", default=None, nargs="?",
                    const="artifacts/bench/fig2_scaling.json",
                    help="fig2_scaling artifact feeding the scaling@ leg "
                         "(flag alone uses the full-run path; under "
                         "--smoke the bare flag points at the smoke "
                         "artifact; missing file = skip)")
    ap.add_argument("--scaling-baseline",
                    default="artifacts/bench/fig2_scaling_baseline.json",
                    help="committed scaling baseline (guard references; "
                         "missing file = references only from overrides)")
    ap.add_argument("--max-pallas-over-bsp", type=float, default=1.5,
                    help="in-run health bound: pallas_step/bsp "
                         "wall-per-task ratio at the guard D above which "
                         "a weak-efficiency regression FAILs")
    ap.add_argument("--min-gather-speedup", type=float, default=0.9,
                    help="chunked/monolithic gather speedup at D>=16 "
                         "below which the ablation check FAILs (in-run "
                         "ratio, no slow-runner escape)")
    ap.add_argument("--serve", default=None, nargs="?",
                    const="artifacts/bench/serve_taskbench.json",
                    help="benchmarks/serve_taskbench artifact feeding the "
                         "serving leg (flag alone uses the default path; "
                         "missing file = skip)")
    ap.add_argument("--serve-baseline",
                    default="artifacts/bench/serve_taskbench_baseline.json",
                    help="committed serving baseline (p99 references; "
                         "missing file = references only from overrides)")
    ap.add_argument("--min-slot-utilization", type=float, default=0.5,
                    help="in-run health bound: slot utilization below "
                         "which a p99 regression FAILs (idle slots + slow "
                         "requests = admission broke, not the runner)")
    a = ap.parse_args(argv)
    trace_path = a.trace
    if trace_path is None and a.smoke:
        trace_path = "artifacts/bench/overhead_decomposition_smoke.json"
    max_visible = a.max_visible
    if max_visible is None:
        max_visible = 1.0 if a.smoke else 0.5
    with open(a.current) as f:
        current = json.load(f)
    with open(a.baseline) as f:
        baseline = json.load(f)
    cost_model = None
    if a.cost_model:
        try:
            with open(a.cost_model) as f:
                cost_model = json.load(f)
        except FileNotFoundError:
            print(f"floor_guard: cost model {a.cost_model} absent "
                  f"(calibration checks skipped)")
    trace_art = None
    if trace_path:
        try:
            with open(trace_path) as f:
                trace_art = json.load(f)
        except FileNotFoundError:
            print(f"floor_guard: trace artifact {trace_path} absent "
                  f"(trace health leg skipped)")
    chaos_art = None
    if a.chaos:
        try:
            with open(a.chaos) as f:
                chaos_art = json.load(f)
        except FileNotFoundError:
            print(f"floor_guard: chaos artifact {a.chaos} absent "
                  f"(resilience leg skipped)")
    scaling_path = a.scaling
    if scaling_path == "artifacts/bench/fig2_scaling.json" and a.smoke:
        scaling_path = "artifacts/bench/fig2_scaling_smoke.json"
    scaling_art = scaling_base = None
    if scaling_path:
        try:
            with open(scaling_path) as f:
                scaling_art = json.load(f)
        except FileNotFoundError:
            print(f"floor_guard: scaling artifact {scaling_path} absent "
                  f"(scaling@ leg skipped)")
        if scaling_art is not None:
            try:
                with open(a.scaling_baseline) as f:
                    scaling_base = json.load(f)
            except FileNotFoundError:
                print(f"floor_guard: scaling baseline {a.scaling_baseline} "
                      f"absent (scaling@weak judged only via overrides)")
    serve_art = serve_base = None
    if a.serve:
        try:
            with open(a.serve) as f:
                serve_art = json.load(f)
        except FileNotFoundError:
            print(f"floor_guard: serve artifact {a.serve} absent "
                  f"(serving leg skipped)")
        if serve_art is not None:
            try:
                with open(a.serve_baseline) as f:
                    serve_base = json.load(f)
            except FileNotFoundError:
                print(f"floor_guard: serve baseline {a.serve_baseline} "
                      f"absent (serve@p99 judged only via overrides)")
    failures = check(current, baseline, a.factor, a.min_amortization,
                     cost_model, trace_art, max_visible,
                     a.max_exchange_fraction, chaos_art,
                     a.max_recovery_tax, a.max_armor_tax,
                     scaling_art, scaling_base,
                     a.max_pallas_over_bsp, a.min_gather_speedup,
                     serve_art, serve_base, a.min_slot_utilization)
    for msg in failures:
        print(f"floor_guard: FAIL: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
