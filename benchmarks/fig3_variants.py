"""Fig 3: throughput of backend build-option variants at a fixed grain.

Paper: Charm++ builds (Default / 8-byte priority / SHMEM transport /
Combined / Simplified scheduling) on the stencil pattern, 8 nodes, grain
4096 — finding transport moves throughput (~5.7%), scheduling-path changes
don't, i.e. communication latency dominates at fine grain.

Our variants of the AMT-analogue (`overlap`) backend map the same axes:
  default            ppermute halos, interior-first overlap  (Default)
  no_overlap         boundary-first, no latency hiding       (Simple Sched.)
  allgather          whole-ring transport                    (SHMEM/NIC swap)
  allgather+no_ovl   both                                    (Combined-like)
  unroll4            scan unrolled x4                        (sched. path)
plus `bsp_scan` (per-step collective, no overdecomposition advantage) as the
non-AMT reference.
Output: artifacts/bench/fig3.csv.
"""
from __future__ import annotations

import argparse

from benchmarks.common import (
    SweepSpec,
    backend_options_args,
    parse_backend_options,
    run_worker,
    write_csv,
)

VARIANTS = (
    ("overlap", "default", {}),
    ("overlap", "no_overlap", {"overlap": False}),
    ("overlap", "allgather", {"halo_via": "allgather"}),
    ("overlap", "allgather+no_ovl", {"halo_via": "allgather",
                                     "overlap": False}),
    ("overlap", "unroll4", {"unroll": 4}),
    ("bsp_scan", "bsp_scan", {}),
)


def run(devices: int = 8, od: int = 8, grain: int = 4096, steps: int = 50,
        reps: int = 5, options=None, verbose: bool = True):
    base_options = dict(options or {})
    rows_csv = []
    results = {}
    for runtime, label, vopts in VARIANTS:
        spec = SweepSpec(
            runtime=runtime, pattern="stencil_1d", devices=devices,
            overdecomposition=od, steps=steps, grains=(grain,), reps=reps,
            # each variant's own knobs win over the CLI-wide base options
            options={**base_options, **vopts},
        )
        rows = run_worker(spec)
        r = rows[0]
        if "skip" in r:
            continue
        results[label] = r["rate"]
        rows_csv.append([label, runtime, grain, devices, od, r["rate"],
                         r["wall"]])
        if verbose:
            print(f"fig3 {label:18s} {r['rate']/1e9:8.3f} GFLOP/s "
                  f"(wall {r['wall']*1e3:.1f} ms)", flush=True)
    if verbose and "default" in results:
        base = results["default"]
        print("\nrelative to default:")
        for label, rate in results.items():
            print(f"  {label:18s} {rate/base*100:6.1f}%")
    path = write_csv(
        "fig3.csv",
        ["variant", "runtime", "grain", "devices", "overdecomposition",
         "flops_per_s", "wall_s"],
        rows_csv,
    )
    if verbose:
        print(f"wrote {path}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--od", type=int, default=8)
    ap.add_argument("--grain", type=int, default=4096)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--paper", action="store_true")
    backend_options_args(ap)
    a = ap.parse_args(argv)
    steps, reps = (1000, 5) if a.paper else (a.steps, a.reps)
    run(devices=a.devices, od=a.od, grain=a.grain, steps=steps, reps=reps,
        options=parse_backend_options(a))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
