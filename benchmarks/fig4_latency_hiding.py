"""Fig 4: latency hiding via concurrent multi-graph ensembles (paper §6.2).

The AMT headline claim: when each core owns MORE than one task graph, a
runtime that can execute graph A's ready tasks while graph B's messages are
in flight hides communication — so ensemble wall time grows SUBLINEARLY in
the number of concurrent graphs K, while a BSP runtime (no such freedom,
round-robin supersteps) pays the full serial sum.

Sweep: K = 1..8 stencil graphs per run, small grains (communication NOT
negligible), `overlap` vs `bsp` (plus `bsp_scan` to separate dispatch
amortization from scheduling freedom). Each worker times BOTH the
concurrent ensemble and the same K graphs run serially back-to-back, so
the concurrency ratio wall(concurrent)/wall(serial) is self-normalized
(same process, devices, compile state) rather than relying on a separately
measured K=1 point. Ratio < 1 means the runtime overlapped work across
graphs; round-robin backends sit at ~1 by construction. Outputs:

  artifacts/bench/fig4.csv    one row per (backend, K, grain)
  artifacts/bench/fig4.json   summary incl. concurrency ratios per (K, grain)
"""
from __future__ import annotations

import argparse
import json

from benchmarks.common import (
    SweepSpec,
    backend_options_args,
    bench_path,
    parse_backend_options,
    run_worker,
    write_csv,
)

from repro.configs.taskbench import PRESETS


def run(devices: int = 4, steps: int = 100, reps: int = 5,
        grains=(1, 8, 64), ensemble_sizes=(1, 2, 4, 8),
        overdecomposition: int = 8, payload: int = 64,
        backends=("overlap", "bsp", "bsp_scan"), options=None,
        verbose: bool = True):
    rows_out = []
    ratios = {}  # (backend, grain) -> {K: concurrent/serial}
    walls = {}  # (backend, K, grain) -> ensemble wall
    for k in ensemble_sizes:
        # all backends measured back-to-back in ONE worker process so their
        # wall ratio is not polluted by scheduling differences across workers
        spec = SweepSpec(
            runtime=backends[0], compare_runtimes=tuple(backends),
            pattern="stencil_1d", devices=devices,
            overdecomposition=overdecomposition, steps=steps,
            grains=tuple(grains), reps=reps, payload=payload, ensemble=k,
            serial_baseline=k > 1, options=dict(options or {}),
        )
        rows = run_worker(spec)
        for r in rows:
            backend = r["runtime"]
            if "skip" in r:
                if verbose:
                    print(f"fig4 {backend:9s} K={k} grain={r['grain']}: "
                          f"skip — {r['skip']}", flush=True)
                continue
            serial = r.get("serial_wall")
            ratio = r["wall"] / serial if serial else None
            if ratio is not None:
                ratios.setdefault((backend, r["grain"]), {})[k] = ratio
            walls[(backend, k, r["grain"])] = r["wall"]
            rows_out.append([backend, k, r["grain"], r["wall"],
                             serial if serial is not None else "",
                             f"{ratio:.4f}" if ratio is not None else "",
                             r["gran_us"], r["rate"], r["tasks"],
                             r["dispatches"]])
        if verbose:
            for backend in backends:
                shown = ", ".join(
                    f"g{r['grain']}={r['wall'] * 1e3:.1f}ms"
                    for r in rows if r["runtime"] == backend and "skip" not in r)
                if shown:
                    print(f"fig4 {backend:9s} K={k}: {shown}", flush=True)

    # Concurrency ratio: ensemble wall / serial-sum wall. < 1.0 = the
    # runtime overlapped one graph's communication/dispatch with another's
    # compute; round-robin backends cannot and sit at ~1.
    summary = {
        backend_grain[0]: {}
        for backend_grain in ratios
    }
    for (backend, grain), by_k in sorted(ratios.items()):
        summary[backend][str(grain)] = {str(k): v for k, v in sorted(by_k.items())}

    # The headline comparison: overlap's ensemble wall relative to bsp's at
    # the same K/grain. Falling with K = overlap's single-program schedule
    # absorbs per-graph costs that bsp's round-robin dispatch pays K times.
    overlap_over_bsp = {}
    for (backend, k, grain), wall in sorted(walls.items()):
        if backend != "overlap":
            continue
        bsp_wall = walls.get(("bsp", k, grain))
        if bsp_wall:
            overlap_over_bsp.setdefault(str(grain), {})[str(k)] = wall / bsp_wall

    path_csv = write_csv(
        "fig4.csv",
        ["backend", "ensemble_k", "grain", "wall_s", "serial_wall_s",
         "concurrent_over_serial", "granularity_us", "flops_per_s", "tasks",
         "dispatches"],
        rows_out,
    )
    path_json = bench_path("fig4.json")
    with open(path_json, "w") as f:
        json.dump({
            "devices": devices, "steps": steps,
            "overdecomposition": overdecomposition,
            "concurrent_over_serial": summary,
            "overlap_over_bsp": overlap_over_bsp,
        }, f, indent=2)
    if verbose:
        for backend, by_grain in summary.items():
            for grain, by_k in by_grain.items():
                print(f"fig4 {backend:9s} grain={grain}: "
                      f"concurrent/serial = "
                      + ", ".join(f"K{k}:{v:.2f}" for k, v in by_k.items()))
        for grain, by_k in overlap_over_bsp.items():
            print(f"fig4 overlap/bsp grain={grain}: "
                  + ", ".join(f"K{k}:{v:.2f}" for k, v in by_k.items()))
        print(f"wrote {path_csv} and {path_json}")
    return {"concurrent_over_serial": summary,
            "overlap_over_bsp": overlap_over_bsp}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--steps", type=int, default=None,
                    help="override the preset's step count")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--preset", default="fig4", choices=sorted(PRESETS))
    backend_options_args(ap)
    a = ap.parse_args(argv)
    cfg = PRESETS[a.preset]
    opts = parse_backend_options(a)
    run(devices=a.devices, steps=a.steps or cfg.steps,
        reps=a.reps or cfg.reps, grains=cfg.grains,
        ensemble_sizes=cfg.ensemble_sizes,
        overdecomposition=cfg.overdecomposition[0], payload=cfg.payload,
        backends=cfg.runtimes, options=opts)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
