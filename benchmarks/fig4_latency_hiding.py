"""Fig 4: latency hiding via concurrent multi-graph ensembles (paper §6.2).

The AMT headline claim: when each core owns MORE than one task graph, a
runtime that can execute graph A's ready tasks while graph B's messages are
in flight hides communication — so ensemble wall time grows SUBLINEARLY in
the number of concurrent graphs K, while a BSP runtime (no such freedom,
round-robin supersteps) pays the full serial sum.

Sweep: K = 1..8 stencil graphs per run, small grains (communication NOT
negligible), `overlap` vs `bsp` (plus `bsp_scan` to separate dispatch
amortization from scheduling freedom), and — since the double-buffered
deep-halo pipeline landed — `pallas_step` in both schedules: the pipelined
default and the `pipeline=False` serial-exchange ablation (rows
``pallas_step`` / ``pallas_step[nopipe]``), so the latency-hiding figure
includes the repo's fastest backend. pallas_step runs at its own (larger)
overdecomposition: the deep-halo pipeline needs a block wide enough for an
interior that covers the exchange (kernels/schedule.py), and the
concurrency ratio is self-normalized per backend so the width difference
does not pollute the cross-backend reading. Each worker times BOTH the
concurrent ensemble and the same K graphs run serially back-to-back, so
the concurrency ratio wall(concurrent)/wall(serial) is self-normalized
(same process, devices, compile state) rather than relying on a separately
measured K=1 point. Ratio < 1 means the runtime overlapped work across
graphs; round-robin backends sit at ~1 by construction. Outputs:

  artifacts/bench/fig4.csv    one row per (backend, K, grain)
  artifacts/bench/fig4.json   summary incl. concurrency ratios per (K, grain)

Butterfly rows (``...@fft``): the same sweep repeated on the paper's
NON-LOCAL fft pattern — bsp / bsp_scan / pallas_step (the stride plan's
per-step XOR exchanges through the pair megakernel); overlap sits out
(halo patterns only) — so the latency-hiding artifact covers a scenario
whose messages cross the machine, not just ring neighbors.

``--smoke`` shrinks the sweep to a seconds-long CI guard (2 devices, tiny
steps/K) that exercises every backend row — including the pipelined
pallas_step ensemble path and the butterfly rows — and the artifact
schema; it writes to ``fig4_smoke.{csv,json}`` so the committed full-run
artifacts survive.
"""
from __future__ import annotations

import argparse
import json

from benchmarks.common import (
    SweepSpec,
    backend_options_args,
    bench_path,
    calibrate_worker,
    parse_backend_options,
    run_worker,
    write_csv,
)

from repro.configs.taskbench import PRESETS

#: overdecomposition for the pallas_step rows (block = od * devices/devices
#: = od per device): wide enough that the tuner's covering rule keeps the
#: pipeline on (see kernels/schedule.PIPELINE_EXCHANGE_ROW_STEPS)
PALLAS_OVERDECOMPOSITION = 128

#: variant label -> extra pallas_step options (empty label = the default
#: pipelined schedule; rows surface as "pallas_step" / "pallas_step[nopipe]")
PALLAS_VARIANTS = {
    "": {"steps_per_launch": "auto"},
    "nopipe": {"steps_per_launch": "auto", "pipeline": False},
}

#: butterfly rows: the latency-hiding sweep repeated on the paper's
#: NON-LOCAL fft pattern (XOR stride exchanges instead of ring halos).
#: overlap sits out — it models halo patterns only — so the comparison is
#: bsp's round-robin vs bsp_scan's fused loop vs pallas_step's stride
#: plan (per-step pair megakernel). No blocked variant rides along: a
#: non-halo member pins an ENSEMBLE's cadence to per-step
#: (pallas_step._ensemble_steps_per_launch), so a steps_per_launch row
#: would silently measure the identical schedule at every K >= 2 — the
#: blocked all-gather schedule is measured where it actually executes
#: (single-graph: tests + the pallas_floor butterfly rows).
BUTTERFLY_PATTERN = "fft"


def _backend_label(runtime: str, variant: str, pattern: str = "") -> str:
    label = f"{runtime}[{variant}]" if variant else runtime
    return f"{label}@{pattern}" if pattern else label


def run(devices: int = 4, steps: int = 100, reps: int = 5,
        grains=(1, 8, 64), ensemble_sizes=(1, 2, 4, 8),
        overdecomposition: int = 8, payload: int = 64,
        backends=("overlap", "bsp", "bsp_scan", "pallas_step"),
        pallas_overdecomposition: int = PALLAS_OVERDECOMPOSITION,
        butterfly: bool = True,
        options=None, verbose: bool = True, smoke: bool = False,
        calibrate: bool = False):
    # cost-model snapshot recorded in the artifact: every saved verdict
    # names the constants it was judged under. --calibrate probes fresh
    # (merged into the cache read by the workers' "auto" resolutions);
    # otherwise snapshot the current default (env / cached / analytic).
    if calibrate:
        cost_model = calibrate_worker(devices, payload, smoke=smoke)
        if verbose:
            print(f"calibrated cost model: exchange="
                  f"{cost_model['exchange_row_steps']:.0f} row-steps, "
                  f"launch={cost_model['launch_us']:.1f}us", flush=True)
    else:
        from repro.kernels import probes as _probes

        cost_model = _probes.default_cost_model(
            devices=devices, payload=payload).to_dict()
    classic = tuple(b for b in backends if b != "pallas_step")
    with_pallas = "pallas_step" in backends
    # butterfly rows: overlap models halo patterns only, so it sits out
    bclassic = tuple(b for b in classic if b != "overlap")
    width = devices * overdecomposition
    if butterfly and width & (width - 1):
        # fft graphs require a power-of-two width; constructing one would
        # crash the whole worker before the skip path can answer — drop
        # the rows rather than the benchmark
        print(f"fig4: butterfly rows skipped (width {width} = {devices} "
              f"devices x od {overdecomposition} is not a power of two)")
        butterfly = False
    rows_out = []
    ratios = {}  # (backend, grain) -> {K: concurrent/serial}
    walls = {}  # (backend, K, grain) -> ensemble wall
    for k in ensemble_sizes:
        # all backends measured back-to-back in ONE worker process so their
        # wall ratio is not polluted by scheduling differences across
        # workers; each (spec, pattern-tag) pair labels its rows
        specs = []
        if classic:
            specs.append((SweepSpec(
                runtime=classic[0], compare_runtimes=classic,
                pattern="stencil_1d", devices=devices,
                overdecomposition=overdecomposition, steps=steps,
                grains=tuple(grains), reps=reps, payload=payload, ensemble=k,
                serial_baseline=k > 1, options=dict(options or {}),
            ), ""))
        if with_pallas:
            # pallas_step rides its own worker (larger od, pipeline pair
            # via option_variants) — the concurrency ratio it reports is
            # still within-worker
            specs.append((SweepSpec(
                runtime="pallas_step", pattern="stencil_1d",
                devices=devices,
                overdecomposition=pallas_overdecomposition, steps=steps,
                grains=tuple(grains), reps=reps, payload=payload,
                ensemble=k, serial_baseline=k > 1,
                options=dict(options or {}),
                option_variants=dict(PALLAS_VARIANTS),
            ), ""))
        if butterfly and bclassic:
            specs.append((SweepSpec(
                runtime=bclassic[0], compare_runtimes=bclassic,
                pattern=BUTTERFLY_PATTERN, devices=devices,
                overdecomposition=overdecomposition, steps=steps,
                grains=tuple(grains), reps=reps, payload=payload, ensemble=k,
                serial_baseline=k > 1, options=dict(options or {}),
            ), BUTTERFLY_PATTERN))
        if butterfly and with_pallas:
            # stride plan (per-step pair megakernel); width =
            # devices * od stays a power of two
            specs.append((SweepSpec(
                runtime="pallas_step", pattern=BUTTERFLY_PATTERN,
                devices=devices, overdecomposition=overdecomposition,
                steps=steps, grains=tuple(grains), reps=reps,
                payload=payload, ensemble=k, serial_baseline=k > 1,
                options=dict(options or {}),
            ), BUTTERFLY_PATTERN))
        rows = [(r, tag) for spec, tag in specs for r in run_worker(spec)]
        for r, tag in rows:
            backend = _backend_label(r["runtime"], r.get("variant", ""), tag)
            if "skip" in r:
                if verbose:
                    print(f"fig4 {backend:9s} K={k} grain={r['grain']}: "
                          f"skip — {r['skip']}", flush=True)
                continue
            serial = r.get("serial_wall")
            ratio = r["wall"] / serial if serial else None
            if ratio is not None:
                ratios.setdefault((backend, r["grain"]), {})[k] = ratio
            walls[(backend, k, r["grain"])] = r["wall"]
            rows_out.append([backend, k, r["grain"], r["wall"],
                             serial if serial is not None else "",
                             f"{ratio:.4f}" if ratio is not None else "",
                             r["gran_us"], r["rate"], r["tasks"],
                             r["dispatches"]])
        if verbose:
            shown_backends = list(classic) + (
                [_backend_label("pallas_step", v) for v in PALLAS_VARIANTS]
                if with_pallas else [])
            if butterfly:
                shown_backends += [
                    _backend_label(b, "", BUTTERFLY_PATTERN)
                    for b in bclassic]
                if with_pallas:
                    shown_backends.append(
                        _backend_label("pallas_step", "", BUTTERFLY_PATTERN))
            for backend in shown_backends:
                shown = ", ".join(
                    f"g{r['grain']}={r['wall'] * 1e3:.1f}ms"
                    for r, tag in rows
                    if _backend_label(r["runtime"], r.get("variant", ""),
                                      tag) == backend and "skip" not in r)
                if shown:
                    print(f"fig4 {backend:20s} K={k}: {shown}", flush=True)

    # Concurrency ratio: ensemble wall / serial-sum wall. < 1.0 = the
    # runtime overlapped one graph's communication/dispatch with another's
    # compute; round-robin backends cannot and sit at ~1.
    summary = {
        backend_grain[0]: {}
        for backend_grain in ratios
    }
    for (backend, grain), by_k in sorted(ratios.items()):
        summary[backend][str(grain)] = {str(k): v for k, v in sorted(by_k.items())}

    # The headline comparison: overlap's ensemble wall relative to bsp's at
    # the same K/grain. Falling with K = overlap's single-program schedule
    # absorbs per-graph costs that bsp's round-robin dispatch pays K times.
    overlap_over_bsp = {}
    for (backend, k, grain), wall in sorted(walls.items()):
        if backend != "overlap":
            continue
        bsp_wall = walls.get(("bsp", k, grain))
        if bsp_wall:
            overlap_over_bsp.setdefault(str(grain), {})[str(k)] = wall / bsp_wall

    # pallas_step's pipeline against its own serial-exchange ablation at
    # the same K/grain — the fig4 view of the latency-hiding schedule
    pipe_over_nopipe = {}
    for (backend, k, grain), wall in sorted(walls.items()):
        if backend != "pallas_step":
            continue
        nopipe = walls.get(("pallas_step[nopipe]", k, grain))
        if nopipe:
            pipe_over_nopipe.setdefault(str(grain), {})[str(k)] = wall / nopipe

    stem = "fig4_smoke" if smoke else "fig4"
    path_csv = write_csv(
        f"{stem}.csv",
        ["backend", "ensemble_k", "grain", "wall_s", "serial_wall_s",
         "concurrent_over_serial", "granularity_us", "flops_per_s", "tasks",
         "dispatches"],
        rows_out,
    )
    path_json = bench_path(f"{stem}.json")
    with open(path_json, "w") as f:
        json.dump({
            "devices": devices, "steps": steps,
            "overdecomposition": overdecomposition,
            "pallas_overdecomposition":
                pallas_overdecomposition if with_pallas else None,
            "butterfly_pattern": BUTTERFLY_PATTERN if butterfly else None,
            "concurrent_over_serial": summary,
            "overlap_over_bsp": overlap_over_bsp,
            "pallas_pipe_over_nopipe": pipe_over_nopipe,
            "calibrated": calibrate,
            "cost_model": cost_model,
        }, f, indent=2)
    if verbose:
        for backend, by_grain in summary.items():
            for grain, by_k in by_grain.items():
                print(f"fig4 {backend:20s} grain={grain}: "
                      f"concurrent/serial = "
                      + ", ".join(f"K{k}:{v:.2f}" for k, v in by_k.items()))
        for grain, by_k in overlap_over_bsp.items():
            print(f"fig4 overlap/bsp grain={grain}: "
                  + ", ".join(f"K{k}:{v:.2f}" for k, v in by_k.items()))
        for grain, by_k in pipe_over_nopipe.items():
            print(f"fig4 pallas pipe/nopipe grain={grain}: "
                  + ", ".join(f"K{k}:{v:.2f}" for k, v in by_k.items()))
        print(f"wrote {path_csv} and {path_json}")
    return {"concurrent_over_serial": summary,
            "overlap_over_bsp": overlap_over_bsp,
            "pallas_pipe_over_nopipe": pipe_over_nopipe}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--steps", type=int, default=None,
                    help="override the preset's step count")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--preset", default="fig4", choices=sorted(PRESETS))
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-long CI guard: 2 devices, tiny steps/K, "
                         "every backend row incl. pipelined pallas_step")
    ap.add_argument("--calibrate", action="store_true",
                    help="run the cost-model probes first (merged into "
                         "artifacts/bench/cost_model.json); the snapshot "
                         "is recorded in the artifact JSON")
    backend_options_args(ap)
    a = ap.parse_args(argv)
    cfg = PRESETS[a.preset]
    opts = parse_backend_options(a)
    if a.smoke:
        res = run(devices=2, steps=12, reps=1, grains=(1,),
                  ensemble_sizes=(1, 2), overdecomposition=8,
                  payload=cfg.payload, backends=cfg.runtimes, options=opts,
                  smoke=True, calibrate=a.calibrate)
        # schema guard: every backend (incl. both pallas_step schedules
        # and the butterfly rows' stride/all-gather plans) must have
        # produced concurrency ratios at K=2
        summary = res["concurrent_over_serial"]
        want = [b for b in cfg.runtimes if b != "pallas_step"]
        if "pallas_step" in cfg.runtimes:
            want += ["pallas_step", "pallas_step[nopipe]"]
        want += [_backend_label(b, "", BUTTERFLY_PATTERN)
                 for b in cfg.runtimes if b != "overlap"]
        ok = all(b in summary and summary[b] for b in want)
        if not ok:
            missing = [b for b in want
                       if b not in summary or not summary[b]]
            print(f"fig4 smoke: missing backend rows: {missing}")
        return 0 if ok else 1
    run(devices=a.devices, steps=a.steps or cfg.steps,
        reps=a.reps or cfg.reps, grains=cfg.grains,
        ensemble_sizes=cfg.ensemble_sizes,
        overdecomposition=cfg.overdecomposition[0], payload=cfg.payload,
        backends=cfg.runtimes, options=opts, calibrate=a.calibrate)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
