"""Chaos benchmark: recovery tax of the fault-tolerant ensemble runtime.

Sweeps fault classes x fault rates x device counts through
``repro.resilience.run_resilient`` and records, per configuration:

  clean_wall     production ``execute_ensemble`` wall (best of reps)
  armor_wall     resilient executor wall with NO plan armed — the cost of
                 host-stepped launches + the (disarmed) injection hook
  hook_wall      resilient wall with an armed but EMPTY plan — isolates
                 the per-launch hook itself (must be noise vs armor_wall:
                 the zero-cost contract)
  faulted_wall   resilient wall with the fault plan firing
  recovery_tax   faulted_wall / armor_wall — what the injected faults
                 cost, separated from what the armor costs
  bit_identical  recovery proof: transport/launch/straggler runs must equal
                 the clean outputs bit for bit; member-eviction runs must
                 equal the truncated-steps oracle exactly

Every row runs in a SUBPROCESS with its own forced host device count
(same protocol as benchmarks/common.py). Artifact:
``artifacts/bench/chaos.json`` with a floor_guard-style verdict block;
``floor_guard --chaos`` judges it under the two-signal rule (a tax
regression alone WARNs; only a correctness failure FAILs).

Usage:
  PYTHONPATH=src:. python -m benchmarks.chaos --smoke
  PYTHONPATH=src:. python -m benchmarks.chaos            # full sweep
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Optional, Tuple

from benchmarks.common import ROOT, _run_subprocess_retry, bench_path

SCHEMA = 1
FAULT_CLASSES = ("transport", "launch", "member", "straggler")


@dataclasses.dataclass
class ChaosSpec:
    devices: int = 1
    pattern: str = "stencil_1d"
    width: int = 0  # 0 -> devices x overdecomposition
    overdecomposition: int = 4
    steps: int = 25
    payload: int = 64
    grain: int = 64
    members: int = 2
    steps_per_launch: int = 4
    fault: str = "transport"
    rate: float = 0.3
    seed: int = 0
    reps: int = 3
    warmup: int = 1

    def resolved_width(self) -> int:
        return self.width or self.devices * self.overdecomposition


def _plan_for(spec: ChaosSpec, num_launches: int):
    """A seeded plan for ONE fault class at the requested rate; forced to
    fire at least once (a chaos row that injected nothing proves nothing)."""
    from repro.resilience import FaultPlan, FaultSpec

    plan = FaultPlan.random(
        spec.seed, num_launches=num_launches, num_members=spec.members,
        rate=spec.rate, kinds=(spec.fault,),
        straggler_delay_s=0.02)
    if not plan.specs:
        kw = {"member": spec.members - 1} if spec.fault == "member" else \
            {"delay_s": 0.02} if spec.fault == "straggler" else {}
        plan = FaultPlan(
            (FaultSpec(spec.fault, max(0, num_launches // 2), **kw),),
            seed=spec.seed, note="forced single fault")
    return plan


def _best_wall(fn, reps: int, warmup: int) -> float:
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run_chaos_inproc(spec: ChaosSpec) -> Dict:
    """One chaos measurement in the current process (the --worker body)."""
    import dataclasses as dc

    import jax
    import numpy as np

    from repro.core import GraphEnsemble, KernelSpec, TaskGraph, get_runtime
    from repro.resilience import FaultPlan, run_resilient

    devs = jax.devices()[: spec.devices]
    if len(devs) < spec.devices:
        raise RuntimeError(
            f"need {spec.devices} devices, have {len(jax.devices())}")

    def mk(steps: int, seed: int) -> TaskGraph:
        return TaskGraph(
            steps=steps, width=spec.resolved_width(), pattern=spec.pattern,
            payload=spec.payload, kernel=KernelSpec("compute_bound",
                                                    spec.grain), seed=seed)

    # heterogeneous member lengths: eviction/readmission act on real
    # ragged act schedules, not a degenerate lockstep ensemble
    members = tuple(
        mk(spec.steps - 3 * k, seed=spec.seed + k)
        for k in range(spec.members))
    ens = GraphEnsemble(members)
    rt = get_runtime("pallas_step", devices=devs,
                     steps_per_launch=spec.steps_per_launch)
    ok, why = rt.supports_ensemble(ens)
    if not ok:
        return {"skip": why, **dataclasses.asdict(spec)}

    clean = [np.asarray(o) for o in rt.execute_ensemble(ens)]
    lp = rt.build_ensemble_launches(ens)

    clean_wall = _best_wall(lambda: rt.execute_ensemble(ens),
                            spec.reps, spec.warmup)
    armor_wall = _best_wall(lambda: run_resilient(rt, ens),
                            spec.reps, spec.warmup)
    empty = FaultPlan((), seed=spec.seed, note="armed but empty")
    hook_wall = _best_wall(lambda: run_resilient(rt, ens, plan=empty),
                           spec.reps, 0)

    if spec.fault == "straggler":
        # detection row: one stall at the LAST launch (the self-calibrated
        # deadline needs clean walls first), sized off the run's own wall
        # so it provably blows factor x median regardless of the machine
        from repro.resilience import FaultSpec

        plan = FaultPlan(
            (FaultSpec("straggler", lp.num_launches - 1,
                       delay_s=max(0.05, 2.0 * armor_wall)),),
            seed=spec.seed, note="late stall sized to 2x clean wall")
    else:
        plan = _plan_for(spec, lp.num_launches)

    # the measured faulted run (fresh FaultState per rep: plans are
    # immutable, so every rep injects the identical schedule)
    res = run_resilient(rt, ens, plan=plan)
    faulted_wall = _best_wall(lambda: run_resilient(rt, ens, plan=plan),
                              max(spec.reps - 1, 1), 0)

    # ---- recovery proof --------------------------------------------------
    bit_identical = True
    if spec.fault == "member" and res.evicted:
        # evicted members: compare against the truncated-steps oracle;
        # survivors against the clean run
        oracle_members = tuple(
            dc.replace(g, steps=res.evicted[k]) if k in res.evicted else g
            for k, g in enumerate(members))
        oracle = [np.asarray(o)
                  for o in rt.execute_ensemble(GraphEnsemble(oracle_members))]
        ref = oracle
    else:
        ref = clean
    for got, want in zip(res.outputs, ref):
        if not np.array_equal(np.asarray(got), want):
            bit_identical = False

    row = dataclasses.asdict(spec)
    row.update({
        "num_launches": lp.num_launches,
        "plan": plan.describe(),
        "faults_injected": len(plan.specs),
        "clean_wall": clean_wall,
        "armor_wall": armor_wall,
        "hook_wall": hook_wall,
        "faulted_wall": faulted_wall,
        "armor_tax": armor_wall / clean_wall if clean_wall > 0 else None,
        "hook_tax": hook_wall / armor_wall if armor_wall > 0 else None,
        "recovery_tax": (faulted_wall / armor_wall
                         if armor_wall > 0 else None),
        "retries": res.retries,
        "replays": res.replays,
        "stragglers": res.stragglers,
        "evicted": {str(k): v for k, v in res.evicted.items()},
        "deadline_us": res.deadline_us,
        "deadline_source": res.deadline_source,
        "detection_latency_us": max(
            (e.overshoot_us for e in res.events
             if e.overshoot_us is not None), default=None),
        "bit_identical": bit_identical,
    })
    return row


def run_chaos_worker(spec: ChaosSpec, timeout: int = 1800) -> Dict:
    """Run one chaos row in a subprocess with a forced device count."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={spec.devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + ROOT
    env["JAX_PLATFORMS"] = "cpu"
    env.setdefault("REPRO_COST_MODEL", "off")
    out, attempts = _run_subprocess_retry(
        [sys.executable, "-m", "benchmarks.chaos", "--worker"],
        what=f"chaos worker ({spec.fault}@{spec.devices}d)",
        env=env, timeout=timeout,
        input_text=json.dumps(dataclasses.asdict(spec)))
    row = json.loads(out.stdout.strip().splitlines()[-1])
    if attempts:
        row["worker_retries"] = attempts
    return row


def _verdict(rows: List[Dict]) -> Dict:
    """The floor_guard-facing summary: worst tax per fault class + the
    single correctness bit the two-signal rule hinges on."""
    judged = [r for r in rows if "skip" not in r]
    per_class: Dict[str, Dict] = {}
    for cls in FAULT_CLASSES:
        cls_rows = [r for r in judged if r["fault"] == cls]
        if not cls_rows:
            continue
        per_class[cls] = {
            "rows": len(cls_rows),
            "max_recovery_tax": max(r["recovery_tax"] for r in cls_rows),
            "bit_identical": all(r["bit_identical"] for r in cls_rows),
            "total_retries": sum(r["retries"] for r in cls_rows),
            "total_replays": sum(r["replays"] for r in cls_rows),
        }
    return {
        "recovery_bit_identical": all(r["bit_identical"] for r in judged),
        "max_armor_tax": max((r["armor_tax"] for r in judged), default=None),
        "max_hook_tax": max((r["hook_tax"] for r in judged), default=None),
        "per_class": per_class,
        "devices_proven": sorted(
            {r["devices"] for r in judged if r["bit_identical"]}),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--worker", action="store_true",
                    help="read one ChaosSpec JSON on stdin, print row JSON")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, one rate, devices 1+4")
    ap.add_argument("--devices", type=int, nargs="*", default=None)
    ap.add_argument("--rates", type=float, nargs="*", default=None)
    ap.add_argument("--out", default=None)
    a = ap.parse_args(argv)

    if a.worker:
        spec = ChaosSpec(**json.loads(sys.stdin.read()))
        print(json.dumps(run_chaos_inproc(spec)))
        return 0

    devices = a.devices if a.devices else [1, 4]
    rates = a.rates if a.rates else ([0.3] if a.smoke else [0.1, 0.3, 0.6])
    steps, reps = (13, 2) if a.smoke else (25, 3)
    rows: List[Dict] = []
    for dev in devices:
        for cls in FAULT_CLASSES:
            for rate in rates:
                # straggler rows need enough launches for the detector's
                # warmup window (3 clean walls) before the injected stall
                row_steps = max(steps, 21) if cls == "straggler" else steps
                spec = ChaosSpec(devices=dev, fault=cls, rate=rate,
                                 steps=row_steps, reps=reps,
                                 seed=FAULT_CLASSES.index(cls) * 100 + dev)
                t0 = time.perf_counter()
                row = run_chaos_worker(spec)
                rows.append(row)
                tag = (f"{cls}@{dev}d rate={rate}")
                if "skip" in row:
                    print(f"chaos: {tag}: SKIP ({row['skip']})")
                    continue
                print(f"chaos: {tag}: recovery_tax="
                      f"{row['recovery_tax']:.2f}x "
                      f"(retries={row['retries']} replays={row['replays']} "
                      f"stragglers={row['stragglers']}) "
                      f"bit_identical={row['bit_identical']} "
                      f"[{time.perf_counter() - t0:.0f}s]")
    art = {
        "schema": SCHEMA,
        "smoke": bool(a.smoke),
        "rows": rows,
        "verdict": _verdict(rows),
    }
    out = a.out or bench_path("chaos.json")
    with open(out, "w") as f:
        json.dump(art, f, indent=1)
    v = art["verdict"]
    print(f"chaos: bit-identical recovery on devices "
          f"{v['devices_proven']}: {v['recovery_bit_identical']} "
          f"(armor tax <= {v['max_armor_tax']:.2f}x, hook tax <= "
          f"{v['max_hook_tax']:.2f}x) -> {out}")
    return 0 if v["recovery_bit_identical"] else 1


if __name__ == "__main__":
    sys.exit(main())
