"""Per-backend overhead decomposition from span traces (DESIGN.md §10).

The paper decomposes each system's wall into what the runtime spends
(dispatch, communication) versus what the application gets (compute); this
benchmark produces that figure for OUR backend ladder from the span
tracer: every backend runs every pattern with ``trace=`` on, and each
row's wall is attributed to dispatch / exchange / gather / compute / idle
by interval arithmetic over the recorded spans (repro.obs.decompose).

Two headline artifacts per row ride along:

  * the stacked per-category breakdown (the figure's bars) — e.g.
    `serialized` should be dispatch-dominated at fine grain while
    `bsp_scan`/`fused` collapse everything into one dispatch;
  * for the pipelined pallas_step row, the OVERLAP VERDICT: phase probes
    price what the boundary / exchange / interior phases cost standalone,
    and the combined launch walls then reveal how much exchange time the
    interior actually absorbed (hidden_fraction > 0.5 = the deep-halo
    pipeline is doing its job; the verdict documents the measured value
    either way).

Full mode (default): 4 devices, width 512, tuned ("auto") launch depth —
the configuration PR 4 showed covers the exchange; the verdict is judged
from a dedicated grain=1 row (the METG regime — at the table's coarse
grain the exchange is smaller than probe jitter and the split cannot
resolve it). Smoke mode: 2 devices,
width 64, explicit steps_per_launch=4 (the analytic covering rule
declines tiny shapes, so smoke FORCES the pipelined path to keep the
verdict machinery exercised in CI).

Chrome traces for every row land in artifacts/bench/traces/ (load in
chrome://tracing or ui.perfetto.dev).
"""
from __future__ import annotations

import argparse
import json

from benchmarks.common import (
    SweepSpec,
    bench_path,
    parse_backend_options,
    backend_options_args,
    run_worker,
)

#: every backend in the ladder, fine-to-coarse dispatch granularity
BACKENDS = ("serialized", "bsp", "overlap", "pallas_step", "bsp_scan",
            "fused")

#: patterns every backend supports (overlap constrains the grid: it runs
#: halo patterns + random_nearest only)
PATTERNS = ("stencil_1d", "nearest")

#: extra pallas_step-only rows exercising the stride / all-gather plans
EXTRA_PLANS = ("fft", "spread")

CATEGORIES = ("dispatch", "exchange", "gather", "compute.boundary",
              "compute.interior", "idle")


def _trace_cell(row: dict) -> dict:
    tr = row.get("trace") or {}
    return {
        "wall": row.get("wall"),
        "dispatches": row.get("dispatches"),
        "wall_us": tr.get("wall_us"),
        "fractions": tr.get("fractions"),
        "categories_us": tr.get("categories_us"),
        "overlap": tr.get("overlap"),
        "decisions": tr.get("decisions"),
    }


def _exchange_fraction(cell: dict) -> float:
    fr = cell.get("fractions") or {}
    return float(fr.get("exchange", 0.0)) + float(fr.get("gather", 0.0))


def run(devices: int, width: int, steps: int, grain: int, *,
        pallas_options: dict, options: dict, trace_dir: str,
        verdict_grain: int = 0, timeout: int = 3000) -> dict:
    decomposition: dict = {}
    for pattern in PATTERNS:
        cells: dict = {}
        # the five option-free backends share ONE worker (same device set,
        # same process — the cross-backend fractions are comparable)
        base = run_worker(SweepSpec(
            runtime="", pattern=pattern, devices=devices, width=width,
            steps=steps, grains=(grain,),
            compare_runtimes=tuple(b for b in BACKENDS if b != "pallas_step"),
            options=dict(options), trace=True, trace_dir=trace_dir,
        ), timeout=timeout)
        for row in base:
            if "skip" in row:
                cells[row["runtime"]] = {"skip": row["skip"]}
            else:
                cells[row["runtime"]] = _trace_cell(row)
        ps = run_worker(SweepSpec(
            runtime="pallas_step", pattern=pattern, devices=devices,
            width=width, steps=steps, grains=(grain,),
            options={**options, **pallas_options},
            trace=True, trace_dir=trace_dir,
        ), timeout=timeout)
        cells["pallas_step"] = (
            {"skip": ps[0]["skip"]} if "skip" in ps[0] else
            _trace_cell(ps[0]))
        decomposition[pattern] = cells
    extra: dict = {}
    for pattern in EXTRA_PLANS:
        rows = run_worker(SweepSpec(
            runtime="pallas_step", pattern=pattern, devices=devices,
            width=width, steps=steps, grains=(grain,),
            options=dict(options), trace=True, trace_dir=trace_dir,
        ), timeout=timeout)
        extra[pattern] = (
            {"skip": rows[0]["skip"]} if "skip" in rows[0] else
            _trace_cell(rows[0]))
    pallas = decomposition["stencil_1d"].get("pallas_step", {})
    # the overlap VERDICT row: at coarse grain the exchange is a
    # vanishing fraction of the launch wall (probe jitter alone exceeds
    # it), so full mode re-runs the pipelined stencil row at a FINE grain
    # (the paper's METG regime) where exchange is a real fraction and
    # hidden-vs-visible is resolvable. 0 = judge from the table row
    # (smoke: the forced-S row already is the fine-grain regime).
    verdict_cell = pallas
    if verdict_grain and verdict_grain != grain:
        vrows = run_worker(SweepSpec(
            runtime="pallas_step", pattern="stencil_1d", devices=devices,
            width=width, steps=steps, grains=(verdict_grain,),
            options={**options, **pallas_options},
            trace=True, trace_dir=trace_dir,
        ), timeout=timeout)
        if "skip" not in vrows[0]:
            verdict_cell = _trace_cell(vrows[0])
    return {
        "schema": 1,
        "devices": devices,
        "width": width,
        "steps": steps,
        "grain": grain,
        "pallas_options": pallas_options,
        "decomposition": decomposition,
        "extra_plans": extra,
        "verdict_grain": verdict_grain or grain,
        "verdict_row": verdict_cell,
        # the two headline signals floor_guard's trace leg consumes
        "pallas_overlap": verdict_cell.get("overlap"),
        "pallas_exchange_fraction": _exchange_fraction(verdict_cell),
    }


def print_report(art: dict) -> None:
    for pattern, cells in list(art["decomposition"].items()) + [
            (f"pallas_step plan rows", art["extra_plans"])]:
        print(f"\n-- {pattern}: wall decomposition "
              f"(% of traced extent, D={art['devices']}, "
              f"W={art['width']}, T={art['steps']}, "
              f"grain={art['grain']}) --")
        hdr = f"{'backend':12s}" + "".join(
            f"{c.split('.')[-1]:>10s}" for c in CATEGORIES) + f"{'wall ms':>10s}"
        print(hdr)
        for name, cell in cells.items():
            if "skip" in cell:
                print(f"{name:12s}  skipped: {cell['skip']}")
                continue
            fr = cell.get("fractions") or {}
            bars = "".join(
                f"{100 * float(fr.get(c, 0.0)):>9.1f}%" for c in CATEGORIES)
            print(f"{name:12s}{bars}{1e3 * cell['wall']:>10.2f}")
    ov = art.get("pallas_overlap")
    if ov and ov.get("verdict") in ("hidden", "visible"):
        print(f"\noverlap verdict (pipelined pallas_step, stencil_1d, "
              f"grain={art.get('verdict_grain', art['grain'])}): "
              f"{ov['verdict'].upper()} — {100 * ov['hidden_fraction']:.0f}% "
              f"of exchange wall hidden under interior compute "
              f"({ov['launches']} launches, exchange "
              f"{ov['exchange_per_launch_us']:.1f} us/launch, combined "
              f"launch {ov['combined_launch_us']:.1f} us)")
        if ov["verdict"] == "visible":
            print("  (on this container every forced host device "
                  "multiplexes ONE physical core, so exchange and interior "
                  "compute cannot truly run concurrently — the pipeline's "
                  "measured wins come from fewer dispatch sync points and "
                  "the fused collective, and the verdict machinery is what "
                  "real multi-core/TPU runs will read)")
    elif ov:
        print(f"\noverlap verdict: {ov.get('verdict')} "
              f"({ov.get('reason', '')})")
    else:
        print("\noverlap verdict: none (pallas_step row did not pipeline)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI (2 devices, forced S=4)")
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--width", type=int, default=None)
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--grain", type=int, default=None)
    ap.add_argument("--out", default=None)
    backend_options_args(ap)
    args = ap.parse_args(argv)
    options = parse_backend_options(args)

    if args.smoke:
        devices = args.devices or 2
        width = args.width or 64
        steps = args.steps or 9
        grain = args.grain or 64
        # the analytic covering rule declines tiny blocks; force the
        # pipelined path so CI still exercises the verdict machinery
        pallas_options = {"steps_per_launch": 4}
        verdict_grain = 0  # the smoke table row already is fine-grain
        out = args.out or bench_path("overhead_decomposition_smoke.json")
    else:
        devices = args.devices or 4
        width = args.width or 512
        steps = args.steps or 33
        grain = args.grain or 1024
        pallas_options = {"steps_per_launch": "auto"}
        verdict_grain = 1  # the METG regime: exchange a real fraction
        out = args.out or bench_path("overhead_decomposition.json")

    art = run(devices, width, steps, grain, pallas_options=pallas_options,
              options=options, trace_dir=bench_path("traces"),
              verdict_grain=verdict_grain)
    art["mode"] = "smoke" if args.smoke else "full"
    with open(out, "w") as f:
        json.dump(art, f, indent=1)
    print_report(art)
    print(f"\nwrote {out} (chrome traces in {bench_path('traces')})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
