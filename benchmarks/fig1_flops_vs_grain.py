"""Fig 1 (a) FLOP/s vs grain size, (b) efficiency vs task granularity.

Paper setup: stencil pattern, 1 node (48 cores), 48 tasks — one task per
core. Ours: one "node" of D forced host devices, width = D, all backends
(including `pallas_step`, the fused-timestep megakernel floor).
Output: artifacts/bench/fig1.csv with one row per (backend, grain).
"""
from __future__ import annotations

import argparse

from benchmarks.common import (
    SweepSpec,
    backend_options_args,
    fmt_us,
    metg_from_rows,
    parse_backend_options,
    run_worker,
    write_csv,
)

BACKENDS = ("fused", "serialized", "bsp", "bsp_scan", "overlap", "pallas_step")


def run(devices: int = 4, steps: int = 50, reps: int = 3,
        grains=(1, 4, 16, 64, 256, 1024, 4096, 16384), payload: int = 64,
        use_pallas: bool = False, options=None, verbose: bool = True):
    rows_out = []
    summary = {}
    opts = dict(options or {})
    if use_pallas:
        opts["use_pallas"] = True
    for backend in BACKENDS:
        spec = SweepSpec(
            runtime=backend, pattern="stencil_1d", devices=devices,
            overdecomposition=1, steps=steps, grains=tuple(grains),
            reps=reps, payload=payload, options=opts,
        )
        rows = run_worker(spec)
        if all("skip" in r for r in rows):
            if verbose:
                print(f"fig1 {backend:12s} n/a — {rows[0]['skip']}",
                      flush=True)
            continue
        res = metg_from_rows(rows)
        summary[backend] = res
        if verbose:
            print(f"fig1 {backend:12s} METG(50%) = {fmt_us(res.metg_us)} us "
                  f"(peak {res.peak_flops_per_second/1e9:.3f} GFLOP/s)",
                  flush=True)
        for r in rows:
            if "skip" in r:
                continue
            eff = r["rate"] / max(res.peak_flops_per_second, 1e-30)
            rows_out.append([backend, r["grain"], r["rate"], r["gran_us"],
                             eff, r["wall"], r["dispatches"]])
    path = write_csv(
        "fig1.csv",
        ["backend", "grain", "flops_per_s", "granularity_us", "efficiency",
         "wall_s", "dispatches"],
        rows_out,
    )
    if verbose:
        print(f"wrote {path}")
    return summary


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--paper", action="store_true",
                    help="paper protocol: 1000 steps, 5 reps")
    backend_options_args(ap)
    a = ap.parse_args(argv)
    steps, reps = (1000, 5) if a.paper else (a.steps, a.reps)
    run(devices=a.devices, steps=steps, reps=reps,
        options=parse_backend_options(a))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
