"""Shared measurement machinery for the Task Bench benchmarks.

All benchmarks follow the paper's protocol (§6): a task graph of `steps`
timesteps x `width` points, the compute-bound kernel with the grain knob
`iterations`, reps with warmup, best-of-reps walls; METG extracted at the
50% efficiency threshold.

Device-count sweeps run in SUBPROCESSES (`run_worker`) so each point gets
its own forced host-device count — the main process never touches
XLA_FLAGS. On this container every host device multiplexes ONE physical
core, so absolute FLOP/s do not scale with devices; efficiency is
peak-normalized per configuration, which keeps the paper's runtime-overhead
reading valid (documented in EXPERIMENTS.md §Reproduction).
"""
from __future__ import annotations

import argparse
import csv
import dataclasses
import json
import os
import subprocess
import sys
from typing import Dict, List, Optional, Sequence, Tuple

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_DIR = os.path.join(ROOT, "artifacts", "bench")


def bench_path(name: str) -> str:
    os.makedirs(BENCH_DIR, exist_ok=True)
    return os.path.join(BENCH_DIR, name)


@dataclasses.dataclass
class SweepSpec:
    runtime: str
    pattern: str = "stencil_1d"
    devices: int = 1
    width: int = 0  # 0 -> devices x overdecomposition
    overdecomposition: int = 1
    steps: int = 50
    payload: int = 64
    grains: Tuple[int, ...] = (1, 16, 256, 4096, 16384)
    reps: int = 3
    warmup: int = 1
    #: K > 1 runs a GraphEnsemble of K independent graphs (distinct seeds,
    #: same pattern/grain) concurrently instead of one graph.
    ensemble: int = 1
    #: with ensemble > 1: also time each member alone, back-to-back, and
    #: report the summed serial wall ("serial_wall") as the no-concurrency
    #: baseline for the same process/devices/compile state.
    serial_baseline: bool = False
    #: measure these runtimes back-to-back in ONE worker process (rows carry
    #: a "runtime" key). Cross-backend wall ratios from a single process are
    #: far less noisy than ratios across separately scheduled workers.
    compare_runtimes: Tuple[str, ...] = ()
    options: Dict = dataclasses.field(default_factory=dict)
    #: label -> extra runtime options, measured back-to-back in the SAME
    #: worker process (rows carry a "variant" key): the option-sweep
    #: analogue of compare_runtimes, e.g. a steps_per_launch ladder.
    option_variants: Dict = dataclasses.field(default_factory=dict)
    #: "fused" times the backend's normal executor (whole loop in jitted
    #: programs); "per_launch" times the host-stepped EnsembleLaunchPlan
    #: (one dispatch + sync per launch — the resilience/serving cadence,
    #: where per-dispatch collective cost is not amortized into a scan).
    dispatch: str = "fused"
    #: record a span trace (repro.obs) in a SEPARATE traced execution after
    #: the timed reps — rows gain a "trace" key with the per-category wall
    #: decomposition. The timed path is untouched (DESIGN.md §10).
    trace: bool = False
    #: when tracing, also write one Chrome trace_event JSON per traced row
    #: into this directory (named <runtime>[_<variant>]_g<grain>.json)
    trace_dir: str = ""

    def resolved_width(self) -> int:
        return self.width or self.devices * self.overdecomposition


def run_sweep_inproc(spec: SweepSpec) -> List[Dict]:
    """Run inside the current process (uses existing jax device set)."""
    import jax

    from repro.core import GraphEnsemble, KernelSpec, TaskGraph, get_runtime

    devs = jax.devices()[: spec.devices]
    if len(devs) < spec.devices:
        raise RuntimeError(
            f"need {spec.devices} devices, have {len(jax.devices())}")
    rows = []
    runtimes = spec.compare_runtimes or (spec.runtime,)
    for grain in spec.grains:
        members = [
            TaskGraph(
                steps=spec.steps,
                width=spec.resolved_width(),
                pattern=spec.pattern,
                payload=spec.payload,
                kernel=KernelSpec("compute_bound", grain),
                seed=k,
            )
            for k in range(max(spec.ensemble, 1))
        ]
        variants = spec.option_variants or {"": {}}
        for name, vlabel in [(n, vl) for n in runtimes for vl in variants]:
            opts = {**spec.options, **variants[vlabel]}
            if spec.trace:
                opts["trace"] = True
            rt = get_runtime(name, devices=devs, **opts)
            serial_wall = None
            if spec.ensemble > 1:
                ens = GraphEnsemble(members)
                ok, why = rt.supports_ensemble(ens)
                if not ok:
                    rows.append({"runtime": name, "variant": vlabel,
                                 "grain": grain, "skip": why})
                    continue
                sample, stats = rt.measure_ensemble(
                    ens, reps=spec.reps, warmup=spec.warmup)
                if spec.serial_baseline:
                    # members differ only in seed (same traced program), so
                    # time ONE member and scale — avoids K redundant compiles
                    serial_wall = spec.ensemble * rt.measure(
                        members[0], reps=spec.reps,
                        warmup=spec.warmup)[0].wall_time
            elif spec.dispatch == "per_launch":
                g = members[0]
                ens = GraphEnsemble([g])
                ok, why = rt.supports_ensemble(ens)
                if not ok:
                    rows.append({"runtime": name, "variant": vlabel,
                                 "grain": grain, "skip": why})
                    continue
                sample, stats = rt.measure_launch_plan(
                    ens, reps=spec.reps, warmup=spec.warmup)
            else:
                g = members[0]
                ok, why = rt.supports(g)
                if not ok:
                    rows.append({"runtime": name, "variant": vlabel,
                                 "grain": grain, "skip": why})
                    continue
                sample, stats = rt.measure(g, reps=spec.reps,
                                           warmup=spec.warmup)
            row = {
                "runtime": name,
                "variant": vlabel,
                "grain": grain,
                "wall": sample.wall_time,
                "flops": sample.total_flops,
                "tasks": sample.num_tasks,
                "cores": sample.cores,
                "gran_us": sample.granularity_us,
                "rate": sample.flops_per_second,
                "dispatches": stats.dispatches,
            }
            if serial_wall is not None:
                row["serial_wall"] = serial_wall
            if spec.trace and spec.ensemble <= 1:
                row["trace"] = _trace_row(rt, members[0], spec,
                                          name, vlabel, grain)
            rows.append(row)
    return rows


def _trace_row(rt, graph, spec: SweepSpec, name: str, vlabel: str,
               grain: int) -> Dict:
    """One traced execution -> the row's decomposition summary (and,
    with ``trace_dir``, a Chrome trace file). Runs AFTER the timed reps so
    the probe/warmup cost of tracing can never leak into the walls."""
    import re

    from repro.obs import summarize, write_chrome_trace

    rt.trace_once(graph)
    summary = summarize(rt.tracer.spans)
    if spec.trace_dir:
        os.makedirs(spec.trace_dir, exist_ok=True)
        label = re.sub(r"[^A-Za-z0-9_.-]+", "-",
                       name + (f"_{vlabel}" if vlabel else "") + f"_g{grain}")
        write_chrome_trace(
            os.path.join(spec.trace_dir, f"{label}.json"),
            rt.tracer.spans, process_name=label)
    return summary


#: one retry for transient worker deaths (OOM kill, scheduler eviction,
#: wedged XLA compile hitting the timeout); backoff before it so a loaded
#: host gets a moment to drain
WORKER_RETRIES = 1
WORKER_RETRY_BACKOFF_S = 5.0


def _run_subprocess_retry(cmd, *, what: str, env: Dict, timeout: int,
                          input_text: Optional[str] = None,
                          retries: int = WORKER_RETRIES,
                          backoff_s: float = WORKER_RETRY_BACKOFF_S):
    """Run a benchmark subprocess with per-attempt timeout and retry.

    A sweep is hours of accumulated walls; one transiently dead worker
    must not discard all of it. Returns (CompletedProcess, attempts_used);
    raises RuntimeError naming the failure only once the retry budget is
    spent. The retry count is surfaced in the caller's JSON so an artifact
    judged after a retry says so."""
    import time as _time

    last_err = ""
    for attempt in range(retries + 1):
        if attempt:
            _time.sleep(backoff_s * attempt)
        try:
            out = subprocess.run(
                cmd, input=input_text, capture_output=True, text=True,
                timeout=timeout, env=env, cwd=ROOT)
        except subprocess.TimeoutExpired:
            last_err = f"timed out after {timeout}s"
            continue
        if out.returncode == 0:
            return out, attempt
        last_err = out.stderr[-4000:]
    raise RuntimeError(
        f"{what} failed after {retries + 1} attempts:\n{last_err}")


def run_worker(spec: SweepSpec, timeout: int = 3000) -> List[Dict]:
    """Run a sweep in a subprocess with its own forced device count.

    Each attempt gets the full ``timeout``; a transient worker death
    (timeout / nonzero exit) is retried once with backoff, and rows from a
    retried worker carry ``worker_retries`` so the artifact records it."""
    payload = json.dumps(dataclasses.asdict(spec))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={spec.devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + ROOT
    env["JAX_PLATFORMS"] = "cpu"
    out, attempts = _run_subprocess_retry(
        [sys.executable, "-m", "benchmarks._worker"],
        what=f"sweep worker ({spec.runtime}, {spec.devices}d)",
        env=env, timeout=timeout, input_text=payload)
    rows = json.loads(out.stdout.strip().splitlines()[-1])
    if attempts:
        for row in rows:
            row["worker_retries"] = attempts
    return rows


def calibrate_worker(devices: int, payload: int = 64, *, smoke: bool = False,
                     out: Optional[str] = None,
                     timeout: int = 600) -> Dict:
    """Run the cost-model probes in a subprocess and return the model dict.

    A subprocess for the same reason as ``run_worker``: the probes need
    their own forced host-device count, and the main process never touches
    XLA_FLAGS. The calibration is merged into ``out`` (default: the cache
    file every later "auto" resolution reads), and the returned snapshot
    is what the benchmarks embed in their artifact JSON — every saved
    verdict names the constants it was judged under."""
    out = out or bench_path("cost_model.json")
    cmd = [sys.executable, "-m", "repro.kernels.probes",
           "--devices", str(devices), "--payload", str(payload),
           "--out", out, "--json"]
    if smoke:
        cmd.append("--smoke")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + ROOT
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)  # the probes CLI sets its own forcing flag
    res, attempts = _run_subprocess_retry(
        cmd, what=f"calibration ({devices}d)", env=env, timeout=timeout)
    lines = res.stdout.strip().splitlines()
    # stdout: "cost model [...] -> path", describe() line, then the JSON
    start = next(i for i, ln in enumerate(lines) if ln.startswith("{"))
    model = json.loads("\n".join(lines[start:]))
    if attempts:
        model["worker_retries"] = attempts
    return model


def gather_impl_worker(devices: int, widths: Tuple[int, ...],
                       payload: int = 64, reps: int = 25,
                       timeout: int = 600) -> Dict[str, Dict[int, float]]:
    """Measure ``gather_global`` transport walls per (impl, width) in a
    subprocess with its own forced device count.

    This is ``probes.probe_gather_impl_us`` — one dispatched collective
    per timed call, median-of-reps (the typical per-dispatch wall; see
    the probe's docstring), the exact table
    ``schedule.choose_gather_impl`` ranks. Returns ``{impl: {width: us}}``
    for impls xla and chunked at the given device count."""
    code = (
        "import json\n"
        "from repro.kernels.probes import probe_gather_impl_us\n"
        f"t = probe_gather_impl_us({devices}, {payload},\n"
        f"    widths={tuple(widths)}, impls=('xla', 'chunked'),\n"
        f"    device_counts=({devices},), reps={reps})\n"
        "print(json.dumps(t))\n"
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + ROOT
    env["JAX_PLATFORMS"] = "cpu"
    out, _ = _run_subprocess_retry(
        [sys.executable, "-c", code],
        what=f"gather transport probe ({devices}d)", env=env,
        timeout=timeout)
    raw = json.loads(out.stdout.strip().splitlines()[-1])
    # json stringifies the int keys; flatten the devices level (single d)
    return {
        impl: {int(w): us for w, us in by_d.get(str(devices), {}).items()}
        for impl, by_d in raw.items()
    }


def metg_from_rows(rows: Sequence[Dict], threshold: float = 0.5,
                   peak: Optional[float] = None):
    from repro.core import GrainSample, compute_metg

    samples = [
        GrainSample(
            iterations=r["grain"], wall_time=r["wall"],
            total_flops=r["flops"], num_tasks=r["tasks"], cores=r["cores"],
        )
        for r in rows if "skip" not in r
    ]
    return compute_metg(samples, threshold=threshold, peak=peak)


def backend_options_args(ap: argparse.ArgumentParser) -> None:
    """Attach the shared backend-option flags to a benchmark CLI.

    Every figure accepts the same two knobs so Pallas variants can be swept
    without code edits (they flow into ``SweepSpec.options`` and from there
    into ``get_runtime(name, **options)``):

      --pallas             shorthand for use_pallas=True (per-body kernels)
      --backend-options    JSON dict of raw runtime options, e.g.
                           '{"combine": "onehot", "unroll": 2}' or
                           '{"steps_per_launch": 8}' (pallas_step temporal
                           blocking; "auto" = VMEM tuner)
    """
    ap.add_argument("--pallas", action="store_true",
                    help="use the Pallas task-body kernels (use_pallas=True)")
    ap.add_argument("--backend-options", default=None, metavar="JSON",
                    help="extra runtime options as a JSON dict")


def parse_backend_options(args: argparse.Namespace) -> Dict:
    """Decode --backend-options and fold --pallas in: the final options dict."""
    if getattr(args, "backend_options", None):
        opts = json.loads(args.backend_options)
        if not isinstance(opts, dict):
            raise SystemExit(
                f"--backend-options must be a JSON object, got {opts!r}")
    else:
        opts = {}
    if getattr(args, "pallas", False):
        opts["use_pallas"] = True
    return opts


def write_csv(name: str, header: Sequence[str], rows: Sequence[Sequence]):
    path = bench_path(name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


def fmt_us(v: Optional[float]) -> str:
    return "unreached" if v is None else f"{v:.1f}"
