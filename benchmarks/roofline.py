"""Roofline table assembly: reads artifacts/dryrun/*.json (written by
repro.launch.dryrun) and renders the per-(arch x shape x mesh) roofline
terms, dominant bottleneck, and useful-FLOPs ratio.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline [--mesh pod16x16] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from benchmarks.common import ROOT, write_csv

DRYRUN_DIR = os.path.join(ROOT, "artifacts", "dryrun")


def load(mesh: str = "pod16x16") -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def render(records: List[Dict], md: bool = False) -> str:
    lines = []
    if md:
        lines.append(
            "| arch | shape | compute_s | memory_s | collective_s | "
            "dominant | model/HLO flops | peak GB/dev |")
        lines.append("|---|---|---|---|---|---|---|---|")
    rows_csv = []
    for r in records:
        if r.get("status") == "skip":
            if md:
                lines.append(
                    f"| {r['arch']} | {r['shape']} | — | — | — | skip | — | — |")
            rows_csv.append([r["arch"], r["shape"], "", "", "", "skip", "",
                             ""])
            continue
        rl = r["roofline"]
        peak_gb = r["memory"]["peak_bytes"] / 1e9
        ratio = rl["useful_flops_ratio"]
        if md:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3e} | "
                f"{rl['memory_s']:.3e} | {rl['collective_s']:.3e} | "
                f"{rl['dominant']} | {ratio:.3f} | {peak_gb:.2f} |")
        rows_csv.append([
            r["arch"], r["shape"], rl["compute_s"], rl["memory_s"],
            rl["collective_s"], rl["dominant"], ratio, peak_gb,
        ])
    write_csv(
        "roofline.csv",
        ["arch", "shape", "compute_s", "memory_s", "collective_s",
         "dominant", "useful_flops_ratio", "peak_gb_per_dev"],
        rows_csv,
    )
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--md", action="store_true")
    a = ap.parse_args(argv)
    records = load(a.mesh)
    if not records:
        print(f"no dry-run artifacts for mesh {a.mesh}; run "
              f"`python -m repro.launch.dryrun --all` first")
        return 1
    txt = render(records, md=True)
    print(txt)
    print(f"\n{len(records)} cells; csv written to artifacts/bench/roofline.csv")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
