"""Benchmark orchestrator: one benchmark per paper table/figure.

  fig1    FLOP/s + efficiency vs grain (paper Fig 1a/1b)
  table2  METG x overdecomposition {1,8,16} (paper Table 2)
  fig2    METG vs device count (paper Fig 2)
  fig3    build-option/transport ablation (paper Fig 3)
  fig4    latency hiding vs ensemble size K (paper §6.2, `-and` graphs)
  floor   pallas_step vs fused wall/step at iterations=1 (megakernel floor)
  roofline  assemble dry-run artifacts (framework §Roofline)

`python -m benchmarks.run` runs the quick preset of everything;
`--only fig1,table2` selects; `--paper` switches to the 1000-step protocol.
`--pallas` / `--backend-options JSON` thread runtime options (Pallas
variants, combine strategy, unroll, pallas_step temporal blocking via
'{"steps_per_launch": 8}' or "auto", ...) through every figure via
SweepSpec.options. CSVs land in artifacts/bench/.
"""
from __future__ import annotations

import argparse
import sys
import time

ALL = ("fig1", "table2", "fig2", "fig3", "fig4", "floor", "roofline")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(ALL))
    ap.add_argument("--paper", action="store_true",
                    help="full paper protocol (1000 steps, 5 reps) — slow")
    from benchmarks.common import backend_options_args, parse_backend_options
    backend_options_args(ap)
    a = ap.parse_args(argv)
    chosen = tuple(a.only.split(",")) if a.only else ALL
    opts = parse_backend_options(a)

    t_all = time.perf_counter()
    steps, reps = (1000, 5) if a.paper else (50, 3)

    if "fig1" in chosen:
        print("=" * 72)
        print("Fig 1: FLOP/s and efficiency vs grain size (stencil, 1 node)")
        print("=" * 72)
        from benchmarks.fig1_flops_vs_grain import run as fig1
        fig1(devices=4, steps=steps, reps=reps, options=opts)

    if "table2" in chosen:
        print("=" * 72)
        print("Table 2: METG x overdecomposition {1, 8, 16}")
        print("=" * 72)
        from benchmarks.table2_metg import run as table2
        table2(devices=4, steps=steps, reps=reps, options=opts)

    if "fig2" in chosen:
        print("=" * 72)
        print("Fig 2: METG vs device count (od 8, 16)")
        print("=" * 72)
        from benchmarks.fig2_scaling import run as fig2
        fig2(device_counts=(1, 2, 4, 8), steps=steps, reps=reps,
             options=opts)

    if "fig3" in chosen:
        print("=" * 72)
        print("Fig 3: transport/scheduling variant ablation (grain 4096)")
        print("=" * 72)
        from benchmarks.fig3_variants import run as fig3
        fig3(devices=8, od=8, steps=steps, reps=max(reps, 5), options=opts)

    if "fig4" in chosen:
        print("=" * 72)
        print("Fig 4: latency hiding — wall vs K concurrent graphs")
        print("=" * 72)
        from benchmarks.fig4_latency_hiding import run as fig4
        # fig4 needs enough steps for per-dispatch cost to rise above timing
        # noise; use its own tuned default unless running the paper protocol.
        fig4(devices=4, options=opts,
             **({"steps": 1000, "reps": 5} if a.paper else {}))

    if "floor" in chosen:
        print("=" * 72)
        print("Floor: pallas_step vs fused wall/step at iterations=1")
        print("=" * 72)
        from benchmarks.pallas_floor import run as floor
        # the FLOOR preset carries the default steps/reps; only the paper
        # protocol overrides them
        floor(devices=1, options=opts,
              **({"steps": 1000, "reps": 5} if a.paper else {}))

    if "roofline" in chosen:
        print("=" * 72)
        print("Roofline (from dry-run artifacts, if present)")
        print("=" * 72)
        from benchmarks.roofline import load, render
        records = load("pod16x16")
        if records:
            print(render(records, md=True))
        else:
            print("(no dry-run artifacts yet — run "
                  "`python -m repro.launch.dryrun --all`)")

    print(f"\ntotal bench time: {time.perf_counter() - t_all:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
