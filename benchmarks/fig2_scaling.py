"""Fig 2: METG vs device count under overdecomposition {8, 16}.

Paper: METG of each system with 1..16 nodes; lower + flatter is better
(flat = communication topology doesn't penalize scale). Ours: device count
sweep via subprocesses; distributed backends only (the shared-memory
backends don't scale past one "node" by construction).
Output: artifacts/bench/fig2.csv.
"""
from __future__ import annotations

import argparse

from benchmarks.common import (
    SweepSpec,
    backend_options_args,
    fmt_us,
    metg_from_rows,
    parse_backend_options,
    run_worker,
    write_csv,
)

BACKENDS = ("bsp", "bsp_scan", "overlap", "fused")


def run(device_counts=(1, 2, 4, 8), ods=(8, 16), steps: int = 50,
        reps: int = 3, grains=(1, 16, 256, 4096, 16384), options=None,
        verbose: bool = True):
    rows_csv = []
    for backend in BACKENDS:
        for od in ods:
            for d in device_counts:
                spec = SweepSpec(
                    runtime=backend, pattern="stencil_1d", devices=d,
                    overdecomposition=od, steps=steps, grains=tuple(grains),
                    reps=reps, options=dict(options or {}),
                )
                rows = run_worker(spec)
                res = metg_from_rows(rows)
                rows_csv.append([
                    backend, od, d,
                    "" if res.metg_us is None else res.metg_us,
                    res.peak_flops_per_second,
                ])
                if verbose:
                    print(f"fig2 {backend:9s} od={od:2d} devices={d:2d} "
                          f"METG = {fmt_us(res.metg_us)} us", flush=True)
    path = write_csv(
        "fig2.csv",
        ["backend", "overdecomposition", "devices", "metg_us",
         "peak_flops_per_s"],
        rows_csv,
    )
    if verbose:
        print(f"wrote {path}")
    return rows_csv


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, nargs="*", default=[1, 2, 4, 8])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--paper", action="store_true")
    backend_options_args(ap)
    a = ap.parse_args(argv)
    steps, reps = (1000, 5) if a.paper else (a.steps, a.reps)
    run(device_counts=tuple(a.devices), steps=steps, reps=reps,
        options=parse_backend_options(a))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
