"""Fig 2: scaling vs device count — METG curves plus the pallas_step
weak/strong-scaling story on simulated meshes up to 64 devices.

Paper: METG of each system with 1..16 nodes; lower + flatter is better
(flat = communication topology doesn't penalize scale). Ours adds the
megakernel: ``pallas_step`` and its unpipelined ablation join the backend
set, and a dedicated scaling sweep runs D in {1, 2, 4, 8, 16, 32, 64}
simulated devices in two modes:

  weak    W = od * D (fixed per-device rows). On this container every
          forced-host device multiplexes ONE physical core, so total
          compute grows with D and raw walls cannot stay flat; the
          scale-invariant metric is wall PER TASK, which at grain=1 is
          almost pure runtime overhead. Weak efficiency(D) =
          wall_per_task(1) / wall_per_task(D): the fraction of the
          1-device per-task cost retained as collectives widen.
  strong  W fixed (default 128), so per-device blocks shrink as D grows.
          Strong efficiency(D) = wall(1) / wall(D): with one physical
          core there is no parallel speedup to find, so the curve reads
          as pure overhead growth (1.0 = free scaling, below = the cost
          of more rendezvous per step).

A gather ablation measures the allgather plan's transport — monolithic
("xla") vs hierarchical ("chunked") ``gather_global`` — back-to-back in
one worker per D at the plan's width, one dispatched collective per
timed call (the ``probe_gather_impl_us`` regime, see
``run_gather_ablation``): the measured basis for
``schedule.choose_gather_impl``'s structural D >= 16 crossover.

Every CSV row carries an execution-mode label: "distributed" backends
shard rows over the forced-host mesh, while "shared_memory_fallback"
names the backends (fused, serialized) that ignore extra devices and run
the whole graph on one — their flat "scaling" curves are a property of
the fallback, not of the runtime, and used to be silently mixed into the
same table.

Outputs: artifacts/bench/fig2.csv (rows, labeled) and
artifacts/bench/fig2_scaling.json (efficiency curves + gather ablation +
the scaling@ guard block floor_guard consumes). ``--smoke`` caps the
sweep at D=8 and writes fig2_scaling_smoke.json for the CI leg.
"""
from __future__ import annotations

import argparse
import json

from benchmarks.common import (
    SweepSpec,
    backend_options_args,
    bench_path,
    calibrate_worker,
    fmt_us,
    gather_impl_worker,
    metg_from_rows,
    parse_backend_options,
    run_worker,
    write_csv,
)

#: the METG table's backend set (satellite fix: pallas_step was missing —
#: the megakernel never appeared in the figure it was built for)
BACKENDS = ("bsp", "bsp_scan", "overlap", "fused", "pallas_step")

#: the scaling sweep's backends: the megakernel, its unpipelined ablation
#: (how much of the curve the boundary/interior split buys), and bsp as
#: the per-launch-dispatch reference the scaling@ guard's health signal
#: compares against in-run.
SCALING_BACKENDS = ("pallas_step", "pallas_step[nopipe]", "bsp")

#: backends that shard rows over the device mesh; everything else runs the
#: whole graph on one device regardless of the requested count
DISTRIBUTED = ("bsp", "bsp_scan", "overlap", "pallas_step")

DEVICE_COUNTS = (1, 2, 4, 8, 16, 32, 64)
GATHER_DEVICES = (8, 16, 32, 64)

#: guard point: weak-scaling efficiency is judged at the largest swept D
#: at or below this count (16 on the full sweep, 8 in --smoke)
GUARD_DEVICES = 16


def _backend_spec(backend: str):
    """Benchmark backend label -> (runtime name, extra options).

    ``name[nopipe]`` is the pipeline ablation; the bracket syntax keeps
    ablations first-class rows without inventing runtime registry names.
    """
    if backend.endswith("[nopipe]"):
        return backend[: -len("[nopipe]")], {"pipeline": False}
    return backend, {}


def exec_mode(backend: str, devices: int) -> str:
    """The CSV's execution-mode label for (backend, device count)."""
    name, _ = _backend_spec(backend)
    if devices <= 1:
        return "single_device"
    if name in DISTRIBUTED:
        return "distributed"
    return "shared_memory_fallback"


def _wall_per_task_us(row) -> float:
    return row["wall"] / max(1, row["tasks"]) * 1e6


def _efficiency_curves(points):
    """[(devices, wall_s, wall_per_task_us), ...] -> the JSON curve dict.

    Efficiencies are anchored at the smallest swept D (the 1-device
    column when present); a sweep that never ran D=1 still gets curves,
    they just read relative to its smallest point.
    """
    points = sorted(points)
    if not points:
        return {}
    d0, wall0, wpt0 = points[0]
    return {
        "devices": [d for d, _, _ in points],
        "wall_s": [w for _, w, _ in points],
        "wall_per_task_us": [w for _, _, w in points],
        "anchor_devices": d0,
        "weak_efficiency": [wpt0 / w if w > 0 else 0.0
                            for _, _, w in points],
        "strong_efficiency": [wall0 / w if w > 0 else 0.0
                              for _, w, _ in points],
    }


def run_metg_table(device_counts=(1, 2, 4, 8), ods=(8, 16), steps=50,
                   reps=3, grains=(1, 16, 256, 4096, 16384), options=None,
                   backends=BACKENDS, verbose=True):
    """The paper-shaped METG table (one row per backend x od x D)."""
    rows_csv = []
    for backend in backends:
        name, extra = _backend_spec(backend)
        for od in ods:
            for d in device_counts:
                spec = SweepSpec(
                    runtime=name, pattern="stencil_1d", devices=d,
                    overdecomposition=od, steps=steps, grains=tuple(grains),
                    reps=reps, options={**extra, **(options or {})},
                )
                rows = run_worker(spec)
                res = metg_from_rows(rows)
                rows_csv.append([
                    backend, "metg", exec_mode(backend, d), od, d,
                    od * d, "",
                    "" if res.metg_us is None else res.metg_us,
                    "", "",
                    res.peak_flops_per_second,
                ])
                if verbose:
                    print(f"fig2 {backend:18s} od={od:2d} devices={d:2d} "
                          f"[{exec_mode(backend, d)}] "
                          f"METG = {fmt_us(res.metg_us)} us", flush=True)
    return rows_csv


def run_scaling(device_counts=DEVICE_COUNTS, od=16, strong_width=128,
                steps=20, reps=2, backends=SCALING_BACKENDS, options=None,
                verbose=True):
    """Weak + strong sweeps at grain=1 (pure overhead) -> (csv rows,
    curves dict keyed backend -> mode -> curve)."""
    rows_csv, curves = [], {}
    for backend in backends:
        name, extra = _backend_spec(backend)
        for mode in ("weak", "strong"):
            points = []
            for d in sorted(device_counts):
                width = od * d if mode == "weak" else strong_width
                if width % d:
                    if verbose:
                        print(f"fig2 {backend:18s} {mode} devices={d:2d} "
                              f"skipped: width {width} % {d} != 0",
                              flush=True)
                    continue
                spec = SweepSpec(
                    runtime=name, pattern="stencil_1d", devices=d,
                    width=width, steps=steps, grains=(1,), reps=reps,
                    options={**extra, **(options or {})},
                )
                row = run_worker(spec)[0]
                if "skip" in row:
                    if verbose:
                        print(f"fig2 {backend:18s} {mode} devices={d:2d} "
                              f"skipped: {row['skip']}", flush=True)
                    continue
                wpt = _wall_per_task_us(row)
                points.append((d, row["wall"], wpt))
                rows_csv.append([
                    backend, mode, exec_mode(backend, d), od, d, width, 1,
                    "", row["wall"], wpt, "",
                ])
                if verbose:
                    print(f"fig2 {backend:18s} {mode} devices={d:2d} "
                          f"W={width:5d} [{exec_mode(backend, d)}] "
                          f"wall/task = {wpt:.2f} us", flush=True)
            curves.setdefault(backend, {})[mode] = _efficiency_curves(points)
    return rows_csv, curves


def run_gather_ablation(device_counts=GATHER_DEVICES, reps=25,
                        options=None, verbose=True):
    """The allgather plan's transport, monolithic ("xla") vs hierarchical
    ("chunked"), measured back-to-back in ONE worker per D at the plan's
    width W = 4D — ``probe_gather_impl_us``: one dispatched collective
    per timed call, MEDIAN-of-reps. This is the per-dispatch regime (the
    cadence of the host-stepped EnsembleLaunchPlan driving the resilience
    engine and the serving loop) and the exact table
    ``schedule.choose_gather_impl`` ranks. The median matters: the full
    D-participant barrier's wall is heavy-tailed by scheduler convoy
    effects on the oversubscribed mesh, and the chunked gather's bounded
    rendezvous width cuts exactly that tail — the typical wall a launch
    loop pays on every dispatch, which best-of-reps would erase. The
    ablation is deliberately NOT an end-to-end step wall: inside the
    fused executor's scanned program the per-step cost is decided by
    collective BARRIER COUNT (all D device threads cross every barrier
    regardless of group size), which flips the verdict to the
    single-barrier monolithic gather and says nothing about rendezvous
    width — that amortized regime is what the weak/strong sweeps above
    already measure."""
    del options  # transport probe: no runtime options to thread
    rows_csv, ablation = [], []
    for d in sorted(device_counts):
        width = 4 * d
        if width % d:
            continue
        table = gather_impl_worker(d, (width,), reps=reps)
        walls = {impl: by_w.get(width) for impl, by_w in table.items()}
        for impl in ("xla", "chunked"):
            if walls.get(impl) is None:
                continue
            rows_csv.append([
                "pallas_step", "gather", exec_mode("pallas_step", d), "",
                d, width, "", f"gather={impl}", walls[impl] * 1e-6,
                "", "",
            ])
        if walls.get("xla") and walls.get("chunked"):
            speedup = walls["xla"] / walls["chunked"]
            ablation.append({
                "devices": d, "width": width,
                "xla_wall_s": walls["xla"] * 1e-6,
                "chunked_wall_s": walls["chunked"] * 1e-6,
                "chunked_speedup": speedup,
            })
            if verbose:
                print(f"fig2 gather ablation devices={d:2d} W={width:4d} "
                      f"chunked speedup x{speedup:.2f}", flush=True)
    return rows_csv, ablation


def _guard_block(curves, ablation, device_counts):
    """The scaling@ leg's input: the weak efficiency of pallas_step at
    the guard point, and the in-run bsp comparison that separates a slow
    runner from a real regression (floor_guard's two-signal contract)."""
    guarded = [d for d in device_counts if d <= GUARD_DEVICES]
    if not guarded:
        return {}
    gd = max(guarded)

    def at(backend, mode, field):
        curve = curves.get(backend, {}).get(mode, {})
        devs = curve.get("devices", [])
        if gd not in devs:
            return None
        return curve[field][devs.index(gd)]

    block = {
        "guard_devices": gd,
        "weak_efficiency": at("pallas_step", "weak", "weak_efficiency"),
        "strong_efficiency": at("pallas_step", "strong",
                                "strong_efficiency"),
        "pallas_wall_per_task_us": at("pallas_step", "weak",
                                      "wall_per_task_us"),
        "bsp_wall_per_task_us": at("bsp", "weak", "wall_per_task_us"),
    }
    abl = [a for a in ablation if a["devices"] >= 16]
    if abl:
        block["chunked_speedup_at_16plus"] = min(
            a["chunked_speedup"] for a in abl)
    return block


CSV_HEADER = [
    "backend", "mode", "exec_mode", "overdecomposition", "devices",
    "width", "grain", "variant", "wall_s", "wall_per_task_us", "metg_us",
]


def run(device_counts=DEVICE_COUNTS, ods=(8, 16), od=16, steps=20,
        reps=2, metg_device_counts=(1, 2, 4, 8), metg_steps=50,
        metg_reps=3, grains=(1, 16, 256, 4096, 16384),
        gather_devices=GATHER_DEVICES, options=None, smoke=False,
        calibrate=True, verbose=True):
    device_counts = tuple(sorted(device_counts))
    gather_devices = tuple(d for d in gather_devices
                           if d <= max(device_counts))
    calibration = None
    if calibrate:
        # one calibration at the largest swept D feeds every "auto"
        # resolution in the workers AND the artifact's provenance block
        calibration = calibrate_worker(max(device_counts), smoke=smoke)
    metg_rows = run_metg_table(
        device_counts=tuple(d for d in metg_device_counts
                            if d <= max(device_counts)),
        ods=ods, steps=metg_steps, reps=metg_reps, grains=grains,
        options=options, verbose=verbose)
    scaling_rows, curves = run_scaling(
        device_counts=device_counts, od=od, steps=steps, reps=reps,
        options=options, verbose=verbose)
    gather_rows, ablation = run_gather_ablation(
        device_counts=gather_devices, verbose=verbose)

    rows_csv = []
    for r in metg_rows + scaling_rows + gather_rows:
        rows_csv.append(r + [""] * (len(CSV_HEADER) - len(r)))
    csv_path = write_csv("fig2.csv", CSV_HEADER, rows_csv)

    data = {
        "device_counts": list(device_counts),
        "overdecomposition": od,
        "steps": steps,
        "reps": reps,
        "smoke": bool(smoke),
        "curves": curves,
        "gather_ablation": ablation,
        "guard": _guard_block(curves, ablation, device_counts),
        "calibration": calibration,
    }
    json_path = bench_path(
        "fig2_scaling_smoke.json" if smoke else "fig2_scaling.json")
    with open(json_path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    if verbose:
        print(f"wrote {csv_path}")
        print(f"wrote {json_path}")
    return data


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, nargs="*",
                    default=list(DEVICE_COUNTS))
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--paper", action="store_true",
                    help="paper-scale steps/reps (hours)")
    ap.add_argument("--smoke", action="store_true",
                    help="cap the sweep at D=8, tiny grids; writes "
                         "fig2_scaling_smoke.json (the CI scaling@ input)")
    ap.add_argument("--no-calibrate", action="store_true",
                    help="skip the cost-model calibration worker")
    backend_options_args(ap)
    a = ap.parse_args(argv)
    if a.smoke:
        counts = tuple(d for d in a.devices if d <= 8) or (1, 2, 4, 8)
        run(device_counts=counts, ods=(16,), steps=10, reps=1,
            metg_device_counts=(1, 4, 8), metg_steps=10, metg_reps=1,
            grains=(1, 256, 4096), gather_devices=(4, 8),
            options=parse_backend_options(a), smoke=True,
            calibrate=not a.no_calibrate)
        return 0
    steps, reps = (50, 5) if a.paper else (a.steps, a.reps)
    run(device_counts=tuple(a.devices), steps=steps, reps=reps,
        options=parse_backend_options(a), calibrate=not a.no_calibrate)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
