"""Subprocess worker: reads a SweepSpec JSON on stdin, prints row JSON.

Invoked by benchmarks/common.py with XLA_FLAGS set BEFORE python starts, so
jax initializes with the requested host device count.
"""
import json
import sys


def main() -> int:
    spec_dict = json.loads(sys.stdin.read())
    from benchmarks.common import SweepSpec, run_sweep_inproc

    spec = SweepSpec(**{k: tuple(v) if k in ("grains", "compare_runtimes")
                        else v for k, v in spec_dict.items()})
    rows = run_sweep_inproc(spec)
    print(json.dumps(rows))
    return 0


if __name__ == "__main__":
    sys.exit(main())
