"""Serving benchmark: tail latency of the continuous-batching fabric.

The serving analogue of Task Bench's METG axis: requests (seeded task
graphs with arrival times, priorities, and priced deadlines) stream into
``repro.serving.ServingFabric``, which packs compatible requests into
stacked cohorts and churns membership mid-run (retire -> re-admit into
freed (K, S) act-mask slots, no recompile). Per configuration the row
records:

  p50/p95/p99 latency   request completion minus arrival, milliseconds
  throughput_rps        completed requests per second of serving wall
  slot_utilization      active-slot-launches / (K x launches)
  cohort census         stacked vs per-step cohorts, membership changes,
                        recompiles (must be 0), stacking-verdict reasons
  bit_identical         every request's output vs its serial same-K
                        oracle (the fabric's correctness contract)

Every row runs in a SUBPROCESS with its own forced host device count
(same protocol as benchmarks/chaos.py). Artifact:
``artifacts/bench/serve_taskbench.json`` with a floor_guard-style verdict
block; ``floor_guard --serve`` judges it under the two-signal rule (a p99
regression alone WARNs; lost bit-identity or cratered utilization FAILs).

Usage:
  PYTHONPATH=src:. python -m benchmarks.serve_taskbench --smoke
  PYTHONPATH=src:. python -m benchmarks.serve_taskbench   # full sweep
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from typing import Dict, List

from benchmarks.common import ROOT, _run_subprocess_retry, bench_path

SCHEMA = 1


@dataclasses.dataclass
class ServeSpec:
    devices: int = 1
    slots: int = 4  # K act-mask slots per cohort
    width: int = 32
    payload: int = 32
    grain: int = 4
    steps_per_launch: int = 4
    requests: int = 18
    arrival_scale_s: float = 0.002  # mean Poisson interarrival gap
    deadline_factor: float = 8.0
    seed: int = 0
    verify: bool = True


def _request_stream(spec: ServeSpec) -> List:
    """A mixed-(pattern, T, W) stream with guaranteed churn structure.

    The head is deterministic: ``slots`` founders plus enough follow-on
    compatible requests that the first stacked cohort MUST retire members
    and re-admit from the queue (the >= 2 membership-changes acceptance
    criterion is structural, not luck). The tail is a seeded-Poisson mix
    over three more compatibility classes — wider stencils (different
    block shape -> second stacked cohort), radius-2 nearest (different
    tables -> third), and all_to_all (allgather plan -> per-step cohort)
    — so the packer demonstrably routes the stream into separate cohorts
    instead of one degraded tuple ensemble."""
    import numpy as np

    from repro.serving import make_request

    rng = np.random.default_rng(spec.seed)
    gaps = rng.exponential(spec.arrival_scale_s, size=max(spec.requests, 1))
    arrivals = np.cumsum(gaps)
    k = spec.slots
    reqs = []

    def add(i: int, **kw):
        reqs.append(make_request(
            i, width=kw.pop("width", spec.width), payload=spec.payload,
            arrival_s=float(arrivals[i]) if i else 0.0,
            seed=spec.seed + 101 * i,
            priority=int(rng.integers(0, 3)), **kw))

    head = min(spec.requests, 2 * k + 2)
    for i in range(head):
        # founders get long-ish staggered horizons; the follow-ons are
        # short so retirements free slots while the queue is non-empty
        steps = 5 + 4 * (i % k) if i < k else 5 + 2 * (i % 3)
        add(i, steps=steps, pattern="stencil_1d")
    tail_mix = (
        dict(pattern="stencil_1d", width=2 * spec.width),
        dict(pattern="nearest", radius=2),
        dict(pattern="all_to_all"),
        dict(pattern="stencil_1d"),
    )
    for i in range(head, spec.requests):
        add(i, steps=int(rng.integers(5, 14)),
            **tail_mix[(i - head) % len(tail_mix)])
    return reqs


def run_serve_inproc(spec: ServeSpec) -> Dict:
    """One serving measurement in the current process (--worker body)."""
    import jax

    from repro.core import get_runtime
    from repro.serving import ServingFabric

    devs = jax.devices()[: spec.devices]
    if len(devs) < spec.devices:
        raise RuntimeError(
            f"need {spec.devices} devices, have {len(jax.devices())}")
    rt = get_runtime("pallas_step", devices=devs,
                     steps_per_launch=spec.steps_per_launch)
    fabric = ServingFabric(rt, max_slots=spec.slots,
                           deadline_factor=spec.deadline_factor,
                           verify=spec.verify)
    reqs = _request_stream(spec)
    rep = fabric.serve(reqs)

    stacked = [c for c in rep.cohorts if c.kind == "stacked"]
    stepwise = [c for c in rep.cohorts if c.kind != "stacked"]
    util_num = sum(c.slot_utilization * c.slots * c.launches_run
                   for c in rep.cohorts)
    util_den = sum(c.slots * c.launches_run for c in rep.cohorts)
    pct = rep.latency_percentiles_s()
    row = dataclasses.asdict(spec)
    row.update({
        "completed": len(rep.completed),
        "deadline_evicted": sum(
            1 for o in rep.outcomes if o.status == "deadline_evicted"),
        "p50_ms": pct["p50"] * 1e3,
        "p95_ms": pct["p95"] * 1e3,
        "p99_ms": pct["p99"] * 1e3,
        "throughput_rps": (len(rep.completed) / rep.wall_s
                           if rep.wall_s > 0 else None),
        "serve_wall_s": rep.wall_s,
        "slot_utilization": util_num / util_den if util_den else 1.0,
        "stacked_cohorts": len(stacked),
        "stepwise_cohorts": len(stepwise),
        "max_stacked_membership_changes": max(
            (c.membership_changes for c in stacked), default=0),
        "mid_run_admissions": sum(c.admitted_mid_run for c in rep.cohorts),
        "recompiles": sum(c.recompiles or 0 for c in rep.cohorts),
        "bit_identical": rep.bit_identical,
        "cohorts": [dataclasses.asdict(c) for c in rep.cohorts],
    })
    return row


def run_serve_worker(spec: ServeSpec, timeout: int = 1800) -> Dict:
    """Run one serving row in a subprocess with a forced device count."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={spec.devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + ROOT
    env["JAX_PLATFORMS"] = "cpu"
    env.setdefault("REPRO_COST_MODEL", "off")
    out, attempts = _run_subprocess_retry(
        [sys.executable, "-m", "benchmarks.serve_taskbench", "--worker"],
        what=f"serve worker (K={spec.slots}@{spec.devices}d)",
        env=env, timeout=timeout,
        input_text=json.dumps(dataclasses.asdict(spec)))
    row = json.loads(out.stdout.strip().splitlines()[-1])
    if attempts:
        row["worker_retries"] = attempts
    return row


def _verdict(rows: List[Dict]) -> Dict:
    """The floor_guard-facing summary. ``dynamic_cohort`` is the
    continuous-batching acceptance bit: some stacked cohort churned
    membership >= 2 times with zero recompiles."""
    judged = [r for r in rows if "skip" not in r]
    return {
        "bit_identical": all(r["bit_identical"] for r in judged),
        "dynamic_cohort": any(
            r["max_stacked_membership_changes"] >= 2
            and r["recompiles"] == 0 for r in judged),
        "min_stacked_cohorts": min(
            (r["stacked_cohorts"] for r in judged), default=0),
        "min_slot_utilization": min(
            (r["slot_utilization"] for r in judged), default=None),
        "total_deadline_evictions": sum(
            r["deadline_evicted"] for r in judged),
        "p99_ms_by_slots": {
            str(r["slots"]): r["p99_ms"] for r in judged},
        "throughput_by_slots": {
            str(r["slots"]): r["throughput_rps"] for r in judged},
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--worker", action="store_true",
                    help="read one ServeSpec JSON on stdin, print row JSON")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, K in {2, 4}, 2 devices")
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--slots", type=int, nargs="*", default=None)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--out", default=None)
    a = ap.parse_args(argv)

    if a.worker:
        spec = ServeSpec(**json.loads(sys.stdin.read()))
        print(json.dumps(run_serve_inproc(spec)))
        return 0

    devices = a.devices if a.devices else (2 if a.smoke else 4)
    slot_sweep = a.slots if a.slots else ([2, 4] if a.smoke else [2, 4, 8])
    requests = a.requests if a.requests else (14 if a.smoke else 32)
    rows: List[Dict] = []
    for k in slot_sweep:
        spec = ServeSpec(devices=devices, slots=k, requests=requests,
                         seed=k)
        t0 = time.perf_counter()
        row = run_serve_worker(spec)
        rows.append(row)
        print(f"serve: K={k}@{devices}d: p50={row['p50_ms']:.1f}ms "
              f"p99={row['p99_ms']:.1f}ms "
              f"thpt={row['throughput_rps']:.1f}req/s "
              f"util={row['slot_utilization']:.2f} "
              f"(stacked={row['stacked_cohorts']} "
              f"churn={row['max_stacked_membership_changes']} "
              f"recompiles={row['recompiles']}) "
              f"bit_identical={row['bit_identical']} "
              f"[{time.perf_counter() - t0:.0f}s]")
    art = {
        "schema": SCHEMA,
        "smoke": bool(a.smoke),
        "rows": rows,
        "verdict": _verdict(rows),
    }
    out = a.out or bench_path("serve_taskbench.json")
    with open(out, "w") as f:
        json.dump(art, f, indent=1)
    v = art["verdict"]
    print(f"serve: bit_identical={v['bit_identical']} "
          f"dynamic_cohort={v['dynamic_cohort']} "
          f"stacked_cohorts>={v['min_stacked_cohorts']} -> {out}")
    ok = (v["bit_identical"] and v["dynamic_cohort"]
          and v["min_stacked_cohorts"] >= 2)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
