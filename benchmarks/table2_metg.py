"""Table 2: METG per backend for overdecomposition {1, 8, 16}, one node.

Paper: width = cores x N for N in {1, 8, 16}; stencil pattern. METG uses
each configuration's own peak (the paper normalizes per system).
Output: artifacts/bench/table2.csv.
"""
from __future__ import annotations

import argparse

from benchmarks.common import (
    SweepSpec,
    fmt_us,
    metg_from_rows,
    run_worker,
    write_csv,
)

BACKENDS = ("fused", "serialized", "bsp", "bsp_scan", "overlap")
ODS = (1, 8, 16)


def run(devices: int = 4, steps: int = 50, reps: int = 3,
        grains=(1, 16, 256, 4096, 16384), verbose: bool = True):
    table = {}
    rows_csv = []
    for backend in BACKENDS:
        for od in ODS:
            spec = SweepSpec(
                runtime=backend, pattern="stencil_1d", devices=devices,
                overdecomposition=od, steps=steps, grains=tuple(grains),
                reps=reps,
            )
            rows = run_worker(spec)
            res = metg_from_rows(rows)
            table[(backend, od)] = res.metg_us
            rows_csv.append([backend, od, devices,
                             "" if res.metg_us is None else res.metg_us,
                             res.peak_flops_per_second])
            if verbose:
                print(f"table2 {backend:12s} od={od:2d} METG = "
                      f"{fmt_us(res.metg_us)} us", flush=True)
    path = write_csv(
        "table2.csv",
        ["backend", "overdecomposition", "devices", "metg_us",
         "peak_flops_per_s"],
        rows_csv,
    )
    if verbose:
        print(f"wrote {path}")
        print("\n| system | 1 task/core | 8 tasks/core | 16 tasks/core |")
        print("|---|---|---|---|")
        for backend in BACKENDS:
            cells = " | ".join(fmt_us(table[(backend, od)]) for od in ODS)
            print(f"| {backend} | {cells} |")
    return table


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--paper", action="store_true")
    a = ap.parse_args(argv)
    steps, reps = (1000, 5) if a.paper else (a.steps, a.reps)
    run(devices=a.devices, steps=steps, reps=reps)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
