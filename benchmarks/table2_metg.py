"""Table 2: METG per backend for overdecomposition {1, 8, 16}, one node.

Paper: width = cores x N for N in {1, 8, 16}; stencil pattern. METG uses
each configuration's own peak (the paper normalizes per system).

Beyond the paper's grid, ``--ensemble`` adds concurrent-multi-graph rows
(Task Bench ``-and``): K independent graphs per run, timed as ONE execution
and folded into a single METG sample via ``metg.combine_grain_samples`` —
so overdecomposition-via-ensembles (more graphs per core) lands next to
overdecomposition-via-width (more points per core) in the same table.

Output: artifacts/bench/table2.csv (one row per backend x od x K).

pallas_step rows honor ``--backend-options '{"steps_per_launch": S}'``
(or "auto"): METG under temporal blocking, with dispatch counts reporting
true launch counts (ceil of T/S).
"""
from __future__ import annotations

import argparse

from benchmarks.common import (
    SweepSpec,
    backend_options_args,
    fmt_us,
    metg_from_rows,
    parse_backend_options,
    run_worker,
    write_csv,
)

BACKENDS = ("fused", "serialized", "bsp", "bsp_scan", "overlap", "pallas_step")
ODS = (1, 8, 16)


def run(devices: int = 4, steps: int = 50, reps: int = 3,
        grains=(1, 16, 256, 4096, 16384), ensembles=(1,), options=None,
        verbose: bool = True):
    table = {}
    rows_csv = []
    opts = dict(options or {})
    for backend in BACKENDS:
        for od in ODS:
            for k in ensembles:
                spec = SweepSpec(
                    runtime=backend, pattern="stencil_1d", devices=devices,
                    overdecomposition=od, steps=steps, grains=tuple(grains),
                    reps=reps, ensemble=k, options=opts,
                )
                rows = run_worker(spec)
                if all("skip" in r for r in rows):
                    if verbose:
                        print(f"table2 {backend:12s} od={od:2d} K={k} n/a — "
                              f"{rows[0]['skip']}", flush=True)
                    continue
                res = metg_from_rows(rows)
                table[(backend, od, k)] = res.metg_us
                rows_csv.append([backend, od, k, devices,
                                 "" if res.metg_us is None else res.metg_us,
                                 res.peak_flops_per_second])
                if verbose:
                    print(f"table2 {backend:12s} od={od:2d} K={k} METG = "
                          f"{fmt_us(res.metg_us)} us", flush=True)
    path = write_csv(
        "table2.csv",
        ["backend", "overdecomposition", "ensemble_k", "devices", "metg_us",
         "peak_flops_per_s"],
        rows_csv,
    )
    if verbose:
        print(f"wrote {path}")
        for k in ensembles:
            label = "" if len(ensembles) == 1 else f" (K={k} graphs)"
            print(f"\n| system{label} | "
                  + " | ".join(f"{od} task{'s' if od > 1 else ''}/core"
                               for od in ODS) + " |")
            print("|---|" + "---|" * len(ODS))
            for backend in BACKENDS:
                if not any((backend, od, k) in table for od in ODS):
                    continue
                cells = " | ".join(
                    fmt_us(table[(backend, od, k)])
                    if (backend, od, k) in table else "n/a"
                    for od in ODS)
                print(f"| {backend} | {cells} |")
    return table


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--paper", action="store_true")
    ap.add_argument("--ensemble", default="1",
                    help="comma-separated ensemble sizes K (default 1)")
    backend_options_args(ap)
    a = ap.parse_args(argv)
    steps, reps = (1000, 5) if a.paper else (a.steps, a.reps)
    opts = parse_backend_options(a)
    ensembles = tuple(int(k) for k in a.ensemble.split(","))
    run(devices=a.devices, steps=steps, reps=reps, ensembles=ensembles,
        options=opts)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
